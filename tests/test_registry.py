"""Method-registry contract tests: every registered method id round-trips
through the one shared driver; multi-seed batching compiles once; the
Pallas gossip backend matches the reference mixing path end to end."""
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import (
    METHODS,
    available_methods,
    build_context,
    get_method,
    run_method,
    run_method_batch,
)

EXPECTED_IDS = {
    "fedspd", "fedspd_permute", "local",
    "dfl_fedavg", "cfl_fedavg", "dfl_fedem", "cfl_fedem",
    "dfl_ifca", "cfl_ifca", "dfl_fedsoft", "cfl_fedsoft",
    "dfl_pfedme", "cfl_pfedme",
}


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(
        n_clients=5, n_per_client=32, rounds=3, tau=1, batch=8,
        avg_degree=3.0, model="mlp", dim=8, n_classes=3,
    )
    data = make_mixture_classification(
        n_clients=5, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    return exp, data


def test_registry_lists_all_method_ids():
    assert set(available_methods()) == EXPECTED_IDS
    assert set(METHODS) == EXPECTED_IDS
    assert len(METHODS) == 13


def test_unknown_method_raises():
    with pytest.raises(KeyError, match="unknown method"):
        get_method("fedmagic")


# full lane round-trips every id; the fast lane keeps one id per adapter
# class (the centralized variants and fedspd_permute only change the mixing
# matrix / gossip wiring, not the adapter plumbing)
_FAST_IDS = {"fedspd", "local", "dfl_fedavg", "dfl_fedem", "dfl_ifca",
             "dfl_fedsoft"}


@pytest.mark.parametrize(
    "method",
    [m if m in _FAST_IDS else pytest.param(m, marks=pytest.mark.slow)
     for m in sorted(EXPECTED_IDS)],
)
def test_method_round_trips_through_driver(setup, method):
    """Every id resolves via the registry and completes one smoke run with
    coherent results — no per-method branching anywhere in the driver."""
    exp, data = setup
    r = run_method(method, data, exp, seed=0, eval_every=2)
    assert r.method == method
    assert np.isfinite(r.mean_acc)
    assert r.acc_per_client.shape == (exp.n_clients,)
    assert len(r.curve) == 2  # rounds 0, 2 at eval_every=2, rounds=3
    if method == "local":
        assert r.comm_bytes == 0
    else:
        assert r.comm_bytes > 0


def test_comm_accounting_matches_topology(setup):
    """Static comm models reflect the transport: centralized star costs
    2·N·model_bytes per round; FedEM multiplies by S models."""
    exp, data = setup
    ctx = build_context(data, exp, seed=0)
    cfl = get_method("cfl_fedavg").comm_model(ctx)
    dfl = get_method("dfl_fedavg").comm_model(ctx)
    em = get_method("dfl_fedem").comm_model(ctx)
    assert cfl.per_round_bytes == 2.0 * ctx.n_clients * ctx.model_bytes
    directed_links = float(ctx.graph.adj.sum() - ctx.graph.n)
    assert dfl.per_round_bytes == directed_links * ctx.model_bytes
    assert em.per_round_bytes == ctx.n_clusters * dfl.per_round_bytes
    assert get_method("fedspd").comm_model(ctx).kind == "tracked"


@pytest.mark.parametrize(
    "method",
    ["dfl_fedavg", pytest.param("fedspd", marks=pytest.mark.slow)],
)
def test_multi_seed_batch_single_compile(setup, method):
    """≥3 seeds produce distinct per-seed results out of ONE jit compile of
    the vmapped step."""
    exp, data = setup
    results = run_method_batch(method, data, exp, seeds=(0, 1, 2),
                               eval_every=2)
    assert len(results) == 3
    assert all(np.isfinite(r.mean_acc) for r in results)
    assert all(r.acc_per_client.shape == (exp.n_clients,) for r in results)
    # different seeds -> different random inits/batches -> different results
    assert len({float(r.mean_acc) for r in results}) > 1
    assert results[0].extras["n_compiles"] == 1


@pytest.mark.slow
def test_fedspd_pallas_backend_matches_reference(setup):
    """Same seed, dense reference vs Pallas streaming kernel: the mixing is
    the same linear map, so the entire run must agree to fp32 tolerance.
    (The fast lane covers the kernel-level parity in test_kernels.py; this
    is the end-to-end cross-check.)"""
    exp, data = setup
    a = run_method("fedspd", data, exp, seed=0, eval_every=100)
    b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   gossip_backend="pallas")
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client, atol=1e-5)
    np.testing.assert_allclose(a.extras["u"], b.extras["u"], atol=1e-5)
    assert abs(a.comm_bytes - b.comm_bytes) < 1e-3 * max(a.comm_bytes, 1.0)


@pytest.mark.slow
def test_fedspd_options_flow_through(setup):
    """Per-run options reach the adapter: tau_final=0 degenerates the final
    phase to the pure Eq. (2) aggregate (different accuracy than the
    personalized run), and DP noise perturbs the trajectory."""
    exp, data = setup
    base = run_method("fedspd", data, exp, seed=0, eval_every=100)
    agg = run_method("fedspd", data, exp, seed=0, eval_every=100,
                     options={"tau_final": 0})
    noisy = run_method("fedspd", data, exp, seed=0, eval_every=100,
                       options={"dp_clip": 1.0, "dp_noise_multiplier": 0.5})
    assert not np.allclose(base.acc_per_client, agg.acc_per_client)
    assert not np.allclose(base.extras["u"], noisy.extras["u"])
