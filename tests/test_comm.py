"""Compressed-communication subsystem (ISSUE-4 tentpole).

Codec round-trips are bounded by the quantization step, stochastic
quantization is unbiased (so gossip stays unbiased in expectation), error
feedback telescopes the residual of biased codecs, ``codec="fp32"`` is a
bit-exact no-op, every one of the 13 method ids runs compressed with
honest wire-byte accounting, and the fused Pallas dequantize+mix path is
parity-tested against the reference codec path and stays a single
``pallas_call`` in the lowered round step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, make_channel
from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import run_method

ALL_IDS = (
    "fedspd", "fedspd_permute", "local",
    "dfl_fedavg", "cfl_fedavg", "dfl_fedem", "cfl_fedem",
    "dfl_ifca", "cfl_ifca", "dfl_fedsoft", "cfl_fedsoft",
    "dfl_pfedme", "cfl_pfedme",
)

INT8_EF = CommConfig(codec="int8", error_feedback=True)


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(
        n_clients=5, n_per_client=32, rounds=3, tau=1, batch=8,
        avg_degree=3.0, model="mlp", dim=8, n_classes=3,
    )
    data = make_mixture_classification(
        n_clients=5, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    return exp, data


# ------------------------------------------------------- codec round-trips


@pytest.mark.parametrize("codec,block", [
    ("int8", 32), ("int8", 256), ("int4", 16), ("int4", 128),
])
def test_quant_roundtrip_error_bounded_by_step(codec, block):
    """|decode(encode(x)) - x| < one quantization step per scale block
    (stochastic rounding moves each value by strictly less than 1 ulp of
    the block's scale), including non-dividing X widths (padded tail)."""
    qmax = {"int8": 127.0, "int4": 7.0}[codec]
    ch = make_channel(CommConfig(codec=codec, block=block), 203)
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(0), (7, 203))
    x_hat, _ = ch.roundtrip(x, jax.random.PRNGKey(1), None)
    nq = -(-203 // block)
    xp = np.pad(np.asarray(x), [(0, 0), (0, nq * block - 203)])
    step = np.abs(xp).reshape(7, nq, block).max(-1) / qmax  # per-block scale
    if codec == "int4":  # int4 ships fp16 scales; the step is the fp16 one
        step = step.astype(np.float16).astype(np.float32)
    err = np.abs(np.asarray(x_hat) - np.asarray(x))
    bound = np.repeat(step, block, axis=1)[:, :203]
    assert (err <= bound + 1e-6).all()


def test_quant_roundtrip_batch_polymorphic():
    """The same channel encodes (X,), (N, X) and FedEM's (S, N, X)."""
    ch = make_channel(CommConfig(codec="int8", block=64), 100)
    key = jax.random.PRNGKey(0)
    for shape in ((100,), (4, 100), (2, 4, 100)):
        x = jax.random.normal(jax.random.PRNGKey(1), shape)
        x_hat, _ = ch.roundtrip(x, key, None)
        assert x_hat.shape == shape
        assert float(jnp.max(jnp.abs(x_hat - x))) < 0.2


def test_topk_roundtrip_keeps_largest_and_zeroes_rest():
    ch = make_channel(CommConfig(codec="topk", k=3), 10)
    x = jnp.asarray([[0.1, -5.0, 0.2, 3.0, -0.3, 0.0, 4.0, 0.05, -0.2, 0.15]])
    x_hat, _ = ch.roundtrip(x, jax.random.PRNGKey(0), None)
    want = jnp.asarray([[0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 4.0, 0.0, 0.0, 0.0]])
    np.testing.assert_allclose(np.asarray(x_hat), np.asarray(want))


def test_stochastic_quantization_is_unbiased():
    """E[decode(encode(x))] = x over the rounding randomness — the property
    that keeps compressed gossip unbiased in expectation: the mix is linear
    in the decoded values, so E[W · decode(encode(x))] = W·x."""
    ch = make_channel(CommConfig(codec="int8", block=64), 64)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 64))
    reps = 600
    acc = jnp.zeros_like(x)
    for i in range(reps):
        x_hat, _ = ch.roundtrip(x, jax.random.PRNGKey(1000 + i), None)
        acc = acc + x_hat
    bias = np.abs(np.asarray(acc / reps - x))
    step = float(jnp.max(jnp.abs(x))) / 127.0
    # mean of `reps` draws each bounded by `step`: ~ step/sqrt(reps) noise
    assert bias.max() < 5.0 * step / np.sqrt(reps) + 1e-6


def test_error_feedback_telescopes_biased_codec():
    """With EF, the residual telescopes: sum_t decode_t = T·x − e_T with
    |e_T| bounded, so the long-run transmitted average converges to x even
    for the (biased) top-k codec — the dropped mass re-enters the stream."""
    ch = make_channel(CommConfig(codec="topk", k=4, error_feedback=True), 32)
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 32))
    ef = ch.init_residual((3,))
    acc = jnp.zeros_like(x)
    rounds = 64
    for t in range(rounds):
        ef_prev = ef
        x_hat, ef = ch.roundtrip(x, jax.random.PRNGKey(t), ef)
        acc = acc + x_hat
        # exact EF identity each round: the residual is what was NOT sent
        np.testing.assert_allclose(np.asarray(ef),
                                   np.asarray(x + ef_prev - x_hat),
                                   atol=1e-5)
    err = np.abs(np.asarray(acc / rounds - x))
    # without EF the k smallest coordinates would NEVER be transmitted
    # (err = |x| there); with EF the average closes to O(1/rounds)
    assert err.max() < np.abs(np.asarray(x)).max() * (32 / 4) / rounds * 2.0


def test_commconfig_validation():
    with pytest.raises(ValueError, match="unknown codec"):
        CommConfig(codec="zfp")
    with pytest.raises(ValueError, match="block"):
        CommConfig(codec="int8", block=0)
    with pytest.raises(ValueError, match="k must"):
        CommConfig(codec="topk", k=-1)
    assert make_channel(CommConfig("fp32"), 100) is None
    assert make_channel(None, 100) is None


# ------------------------------------------------ fp32 = bit-exact no-op


def test_fp32_codec_is_bitexact_noop(setup):
    """codec="fp32" must reproduce the uncompressed packed run bit for bit
    — no channel object, no extra key splits, no residual state — and
    report wire_bytes == comm_bytes."""
    exp, data = setup
    a = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   param_plane=True)
    b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   param_plane=True, comm=CommConfig("fp32"))
    np.testing.assert_array_equal(a.acc_per_client, b.acc_per_client)
    np.testing.assert_array_equal(a.extras["u"], b.extras["u"])
    assert a.wire_bytes == a.comm_bytes
    assert b.wire_bytes == b.comm_bytes == a.comm_bytes
    # and the packed fp32-codec run still matches the pytree reference
    c = run_method("fedspd", data, exp, seed=0, eval_every=100)
    np.testing.assert_allclose(c.acc_per_client, b.acc_per_client, atol=1e-4)


def test_comm_requires_param_plane(setup):
    exp, data = setup
    with pytest.raises(ValueError, match="param_plane"):
        run_method("fedspd", data, exp, seed=0, param_plane=False,
                   comm=INT8_EF)


# ------------------------------------- every method id, compressed wire


@pytest.mark.parametrize("method", ["fedspd", "dfl_fedavg", "dfl_fedem"])
def test_comm_wire_bytes_accounting(setup, method):
    """int8+EF runs end to end (param_plane auto-enabled) and the physical
    wire bytes are <= 30% of the logical fp32 bytes — the static per-model
    ratio of the codec, applied exactly."""
    exp, data = setup
    r = run_method(method, data, exp, seed=0, eval_every=100, comm=INT8_EF)
    assert np.isfinite(r.mean_acc)
    assert r.comm_bytes > 0
    assert r.wire_bytes <= 0.30 * r.comm_bytes


@pytest.mark.slow
def test_comm_runs_all_13_ids_and_matches_fp32(setup):
    """ISSUE-4 acceptance: run_method(m, ..., comm=int8+EF) runs for ALL
    13 method ids, compressed wire bytes <= 30% of the fp32 bytes, and the
    accuracy matches the fp32 baseline within 2 points (for methods whose
    fp32 arm is itself seed-stable at these budgets; the unbiased int8
    channel cannot exceed the method's own cross-seed noise, so the bound
    for noisy, far-from-plateau baselines is max(2 points, 1 fp32 std))."""
    exp, data = setup
    exp = PaperExpConfig(
        n_clients=8, n_per_client=64, rounds=25, tau=2, batch=16,
        avg_degree=3.5, model="mlp", dim=8, n_classes=3,
    )
    data = make_mixture_classification(
        n_clients=8, n_clusters=2, n_per_client=64, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    seeds = (0, 1, 2)
    for method in ALL_IDS:
        fp32 = [run_method(method, data, exp, seed=s, eval_every=10**9,
                           param_plane=True).mean_acc for s in seeds]
        coded = [run_method(method, data, exp, seed=s, eval_every=10**9,
                            comm=INT8_EF) for s in seeds]
        delta = abs(float(np.mean(fp32))
                    - float(np.mean([r.mean_acc for r in coded])))
        tol = max(0.02, float(np.std(fp32)))
        assert delta <= tol, (method, delta, tol, fp32)
        for r in coded:
            if method != "local":  # local transmits nothing
                assert r.wire_bytes <= 0.30 * r.comm_bytes, method


# ------------------------------------- fused Pallas dequantize+mix path


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas_call" in eqn.primitive.name:
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if type(sub).__name__ == "ClosedJaxpr":
                    n += _count_pallas_calls(sub.jaxpr)
                elif type(sub).__name__ == "Jaxpr":
                    n += _count_pallas_calls(sub)
    return n


def test_fused_dequant_kernel_matches_decode_then_mix():
    """gossip_mix_dequant == W @ decode(enc) exactly (interpret mode),
    whole-X and multi-block grids, including a padded tail."""
    from repro.kernels.gossip_mix import gossip_mix_dequant

    ch = make_channel(CommConfig(codec="int8", block=32), 203)
    x = jax.random.normal(jax.random.PRNGKey(2), (6, 203))
    enc = ch.encode(x, jax.random.PRNGKey(3))
    want = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(4), (6, 6)), axis=1
    )
    ref = want @ ch.decode(enc)
    for x_block in (None, 64, 96):  # 96 -> re-planned to a qblock multiple
        got = gossip_mix_dequant(want, enc["q"], enc["scale"], qblock=32,
                                 x_block=x_block, interpret=True)[:, :203]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_comm_backends_parity(setup, backend):
    """The fused Pallas comm path reproduces the reference codec path
    exactly: same keys -> same quantization draws -> identical runs."""
    exp, data = setup
    a = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   comm=INT8_EF, gossip_backend="reference")
    b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   comm=INT8_EF, gossip_backend=backend)
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client, atol=1e-4)
    np.testing.assert_allclose(a.extras["u"], b.extras["u"], atol=1e-4)


def test_comm_round_step_single_pallas_call():
    """The compressed round on the Pallas backend is still exactly ONE
    pallas_call — the fused dequantize+mix kernel; encode and the EF
    update stay XLA-fused elementwise ops outside it."""
    from repro.core.fedspd import FedSPDConfig, init_state, make_round_step
    from repro.core.gossip import GossipSpec, make_mix_fn
    from repro.core.packing import make_pack_spec, pack_state
    from repro.graphs.topology import make_graph
    from repro.models.smallnets import make_classifier

    key = jax.random.PRNGKey(0)
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0,
    )
    _, _, loss_fn, pel_fn, _ = make_classifier("mlp", key, 8, 3)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, 8, 3)
        return p

    fcfg = FedSPDConfig(n_clients=6, n_clusters=2, tau=1, batch=8)
    spec = GossipSpec.from_graph(make_graph("er", 6, 3.0, seed=0))
    ps = make_pack_spec(jax.eval_shape(model_init, key))
    state = pack_state(init_state(key, model_init, fcfg, 32), ps)
    ch = make_channel(INT8_EF, ps.size)
    state = state._replace(ef=ch.init_residual((6,)))
    step = make_round_step(
        loss_fn, pel_fn, spec, fcfg,
        mix_fn=make_mix_fn(spec, "pallas", plane=True, comm=INT8_EF),
        pack_spec=ps, comm=INT8_EF,
    )
    payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    jaxpr = jax.make_jaxpr(step)(state, payload)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1


def test_make_mix_fn_comm_requires_plane():
    from repro.core.gossip import GossipSpec, make_mix_fn
    from repro.graphs.topology import make_graph

    spec = GossipSpec.from_graph(make_graph("er", 4, 2.0, seed=0))
    with pytest.raises(ValueError, match="plane"):
        make_mix_fn(spec, "pallas", plane=False, comm=INT8_EF)


# --------------------------------------------- encoded ppermute payloads


@pytest.mark.slow
def test_ppermute_ships_encoded_payloads():
    """gossip_backend="ppermute" with a codec moves the ENCODED leaves
    over the collective edges and matches the reference comm path."""
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.comm import CommConfig
        from repro.core.gossip import GossipSpec, make_mix_fn
        from repro.graphs.topology import make_graph

        spec = GossipSpec.from_graph(make_graph("er", 6, 3.0, seed=0))
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 100))
        s = jnp.asarray([0, 1, 0, 1, 0, 1])
        ef = jnp.zeros((6, 100))
        key = jax.random.PRNGKey(7)
        for codec in ("int8", "topk"):
            cfg = CommConfig(codec=codec, error_feedback=True)
            a, efa = make_mix_fn(spec, "reference", plane=True,
                                 comm=cfg)(x, s, key, ef)
            b, efb = make_mix_fn(spec, "ppermute", plane=True,
                                 comm=cfg)(x, s, key, ef)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(efa), np.asarray(efb),
                                       atol=1e-5)
        print("encoded ppermute parity OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    assert "encoded ppermute parity OK" in out.stdout


@pytest.mark.slow
def test_sharded_plane_carries_ef_residual():
    """The mesh train loop with a compressing codec + error feedback:
    shard_plane_state must place the (N, X) residual over the client rows
    (plane_state_pspecs grew the ef spec), and the encoded-ppermute round
    must reproduce the single-device reference including the residual."""
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    code = textwrap.dedent("""
        import types
        import jax, jax.numpy as jnp, numpy as np
        from repro.comm import CommConfig, make_channel
        from repro.core.fedspd import (FedSPDConfig, init_state,
                                       make_round_step)
        from repro.core.gossip import GossipSpec, make_mix_fn
        from repro.core.packing import make_pack_spec, pack_state
        from repro.data.synthetic import make_mixture_classification
        from repro.graphs.topology import make_graph
        from repro.launch.sharding import shard_plane_state
        from repro.launch.steps import make_fedspd_train_step
        from repro.models.smallnets import make_classifier

        n = 6
        data = make_mixture_classification(n_clients=n, n_clusters=2,
                                           n_per_client=32, dim=8,
                                           n_classes=4, seed=0)
        key = jax.random.PRNGKey(0)
        _, _, loss_fn, pel_fn, _ = make_classifier("mlp", key, 8, 4)
        def model_init(k):
            p, *_ = make_classifier("mlp", k, 8, 4)
            return p
        bundle = types.SimpleNamespace(init=model_init, loss=loss_fn,
                                       per_example_loss=pel_fn)
        fcfg = FedSPDConfig(n_clients=n, n_clusters=2, tau=1, batch=8)
        gossip = GossipSpec.from_graph(make_graph("er", n, 3.0, seed=0))
        ps = make_pack_spec(jax.eval_shape(model_init, key))
        comm = CommConfig("int8", error_feedback=True)
        ch = make_channel(comm, ps.size)
        payload = {"inputs": jnp.asarray(data.x),
                   "targets": jnp.asarray(data.y)}

        def fresh():
            st = pack_state(init_state(key, model_init, fcfg, 32), ps)
            return st._replace(ef=ch.init_residual((n,)))

        ref_step = make_round_step(
            loss_fn, pel_fn, gossip, fcfg, pack_spec=ps, comm=comm,
            mix_fn=make_mix_fn(gossip, "reference", plane=True, comm=comm))
        ref, _ = jax.jit(ref_step)(fresh(), payload)

        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:n]).reshape(n, 1), ("data", "model"))
        step = make_fedspd_train_step(bundle, gossip, fcfg, pack_spec=ps,
                                      mesh=mesh, donate=True, comm=comm)
        out, _ = step(shard_plane_state(fresh(), mesh), payload)
        np.testing.assert_allclose(np.asarray(out.centers),
                                   np.asarray(ref.centers), atol=2e-5)
        np.testing.assert_allclose(np.asarray(out.ef), np.asarray(ref.ef),
                                   atol=2e-5)
        print("sharded comm+EF parity OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    assert "sharded comm+EF parity OK" in out.stdout


# ---------------------------------------------------- int4 wire bit-packing


@pytest.mark.parametrize("width", [1, 7, 16, 203])
def test_int4_pack_unpack_bit_roundtrip(width):
    """Paired-nibble packing is lossless for every int8 value in [-8, 7],
    including odd widths (one zero pad nibble)."""
    from repro.comm import int4_pack, int4_unpack

    rng = np.random.default_rng(width)
    q = rng.integers(-8, 8, size=(3, width)).astype(np.int8)
    packed = np.asarray(int4_pack(jnp.asarray(q)))
    assert packed.shape == (3, -(-width // 2)) and packed.dtype == np.uint8
    np.testing.assert_array_equal(
        np.asarray(int4_unpack(jnp.asarray(packed), width)), q)


@pytest.mark.parametrize("codec,block,x", [
    ("int8", 32, 203), ("int4", 16, 203), ("int4", 64, 64),
])
def test_serialized_payload_is_wire_exact_and_decodes_identically(
        codec, block, x):
    """``serialize_payload`` IS the wire accounting: its byte length is
    n_messages x wire_model_bytes exactly, and the round-tripped encoding
    decodes bit-identically to the device-side payload."""
    ch = make_channel(CommConfig(codec=codec, block=block), x)
    xs = 2.0 * jax.random.normal(jax.random.PRNGKey(0), (5, x))
    enc = ch.encode(xs, jax.random.PRNGKey(1), rounding="nearest")
    wire = ch.serialize_payload(enc)
    assert len(wire) == 5 * ch.wire_model_bytes
    back = ch.deserialize_payload(wire, batch_prefix=(5,))
    np.testing.assert_array_equal(np.asarray(back["q"]),
                                  np.asarray(enc["q"]))
    np.testing.assert_array_equal(np.asarray(ch.decode(back)),
                                  np.asarray(ch.decode(enc)))
    with pytest.raises(ValueError, match="bytes"):
        ch.deserialize_payload(wire[:-1], batch_prefix=(5,))
