"""Scenario engine: traced per-round graphs, link dropout, stacked data.

Covers the PR-5 acceptance criteria: (a) static-graph callers are
bit-compatible with the pre-refactor program (committed seed-curve
fixture + live closure-vs-traced parity); (b) a whole dynamic-topology
schedule runs through ONE jit compile; (c) time-varying graphs agree
across the gossip backends; (d) dropped links cost zero wire bytes;
(e) the stacked-data ``run_method_batch`` (per-seed datasets, per-seed
graphs) reproduces the per-seed ``run_method`` loop from one compile.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperExpConfig
from repro.core.fedspd import FedSPDConfig, init_state, make_round_step
from repro.core.gossip import GossipSpec
from repro.data.synthetic import make_mixture_classification
from repro.experiments import Scenario, run_method, run_method_batch
from repro.graphs.topology import (
    dropout_schedule,
    make_graph,
    rewire_schedule,
)
from repro.models.smallnets import make_classifier

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "fedspd_static_seed_curve.json")


@pytest.fixture(scope="module")
def setup():
    # MUST match the committed fixture's config block
    exp = PaperExpConfig(n_clients=6, n_per_client=32, rounds=4, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=7, noise=0.3,
    )
    return exp, data


# ------------------------------------------------------------------
# static-graph compatibility (the refactor must not move any bit)
# ------------------------------------------------------------------


def test_static_graph_regression_fixture(setup):
    """The committed seed curve was generated BEFORE the traced-adjacency
    refactor; static-graph callers must still reproduce it (the adj=None
    path is the exact pre-refactor program)."""
    exp, data = setup
    with open(FIXTURE) as f:
        fx = json.load(f)
    r = run_method("fedspd", data, exp, seed=0, eval_every=2)
    np.testing.assert_allclose(r.acc_per_client, fx["acc_per_client"],
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(r.extras["u"]), fx["u"], atol=1e-6)
    np.testing.assert_allclose([c[1] for c in r.curve],
                               [c[1] for c in fx["curve"]], atol=1e-6)
    assert [c[0] for c in r.curve] == [c[0] for c in fx["curve"]]
    np.testing.assert_allclose(r.comm_bytes, fx["comm_bytes"], rtol=1e-6)


def test_traced_adj_matches_static_closure_and_caches_once(setup):
    """Feeding the static adjacency as the TRACED per-round argument must
    reproduce the closure-constant program, and 10 different traced
    matrices must hit one jit cache entry (shape-stable input, no
    recompiles)."""
    exp, data = setup
    n = exp.n_clients
    key = jax.random.PRNGKey(0)
    _, _, loss_fn, pel_fn, _ = make_classifier("mlp", key, 8, 3)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, 8, 3)
        return p

    fcfg = FedSPDConfig(n_clients=n, n_clusters=2, tau=1, batch=8)
    g = make_graph("er", n, 3.0, seed=0)
    spec = GossipSpec.from_graph(g)
    payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))

    s_static = s_traced = init_state(key, model_init, fcfg, 32)
    adj0 = jnp.asarray(g.adj)
    for _ in range(3):
        s_static, _ = step(s_static, payload)
        s_traced, _ = step(s_traced, payload, adj0)
    for a, b in zip(jax.tree.leaves(s_static), jax.tree.leaves(s_traced)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    # recompile guard: 10 rewired rounds, one cache entry for the traced
    # signature (plus the one static-signature entry from above)
    sched = rewire_schedule("er", n, 3.0, rounds=10, p_rewire=0.4, seed=1)
    cache_size = getattr(step, "_cache_size", None)
    entries_before = cache_size() if cache_size else None
    for t in range(10):
        s_traced, _ = step(s_traced, payload, jnp.asarray(sched.adjs[t]))
    if cache_size:  # private jax diagnostic; absent on some versions
        assert cache_size() == entries_before


def test_rewire_scenario_single_compile_through_driver(setup):
    """A 10-round rewire schedule through run_method: one compile of the
    round step end to end (the traced-weight refactor's whole point)."""
    exp, data = setup
    exp10 = dataclasses.replace(exp, rounds=10)
    sched = rewire_schedule("er", exp.n_clients, 3.0, rounds=10,
                            p_rewire=0.4, seed=2)
    r = run_method("fedspd", data, exp10, seed=0, eval_every=100,
                   scenario=Scenario(graph_schedule=sched))
    assert r.extras["n_compiles"] == 1
    assert np.isfinite(r.mean_acc)


# ------------------------------------------------------------------
# backend parity under dynamic topologies
# ------------------------------------------------------------------


def test_dynamic_graph_backend_parity(setup):
    """The same rewire schedule through the dense reference path, the
    Pallas streaming kernel, and the edge-colored permute schedule (built
    from the union graph, masked by the traced adjacency) — one linear
    map, three executions."""
    exp, data = setup
    sched = rewire_schedule("er", exp.n_clients, 3.0, rounds=exp.rounds,
                            p_rewire=0.4, seed=3)
    sc = Scenario(graph_schedule=sched)
    ref = run_method("fedspd", data, exp, seed=0, eval_every=100,
                     scenario=sc)
    pal = run_method("fedspd", data, exp, seed=0, eval_every=100,
                     scenario=sc, gossip_backend="pallas")
    per = run_method("fedspd_permute", data, exp, seed=0, eval_every=100,
                     scenario=sc)
    np.testing.assert_allclose(ref.acc_per_client, pal.acc_per_client,
                               atol=1e-5)
    np.testing.assert_allclose(ref.acc_per_client, per.acc_per_client,
                               atol=1e-5)
    np.testing.assert_allclose(ref.extras["u"], pal.extras["u"], atol=1e-5)
    np.testing.assert_allclose(ref.extras["u"], per.extras["u"], atol=1e-5)


@pytest.mark.slow
def test_dynamic_graph_ppermute_parity(setup):
    """Dropout scenario through the shard_map ppermute schedule (one
    device per client, subprocess): the static collective schedule with
    traced edge masking must match the dense reference."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    code = textwrap.dedent("""
        import numpy as np
        from repro.configs.paper_cnn import PaperExpConfig
        from repro.data.synthetic import make_mixture_classification
        from repro.experiments import Scenario, run_method

        exp = PaperExpConfig(n_clients=6, n_per_client=32, rounds=3, tau=1,
                             batch=8, avg_degree=3.0, model="mlp", dim=8,
                             n_classes=3)
        data = make_mixture_classification(n_clients=6, n_clusters=2,
                                           n_per_client=32, dim=8,
                                           n_classes=3, seed=7, noise=0.3)
        sc = Scenario(dropout=0.4, seed=5)
        a = run_method("fedspd", data, exp, seed=0, eval_every=100,
                       gossip_mode="permute", scenario=sc)
        b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                       gossip_mode="permute", scenario=sc,
                       gossip_backend="ppermute")
        np.testing.assert_allclose(a.acc_per_client, b.acc_per_client,
                                   atol=1e-4)
        np.testing.assert_allclose(a.extras["u"], b.extras["u"], atol=1e-4)
        assert abs(a.comm_bytes - b.comm_bytes) <= 1e-3 * a.comm_bytes
        print("dynamic ppermute parity OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"


# ------------------------------------------------------------------
# dropout semantics
# ------------------------------------------------------------------


def test_dropout_costs_zero_wire_bytes(setup):
    """A dropped link carries nothing: full dropout zeroes the tracked
    comm bytes exactly, partial dropout lands strictly below the static
    run (the accounting reads the traced adjacency, not the topology)."""
    exp, data = setup
    static = run_method("fedspd", data, exp, seed=0, eval_every=100)
    partial = run_method("fedspd", data, exp, seed=0, eval_every=100,
                         scenario=Scenario(dropout=0.5, seed=1))
    total = run_method("fedspd", data, exp, seed=0, eval_every=100,
                       scenario=Scenario(dropout=1.0, seed=1))
    assert total.comm_bytes == 0.0
    assert 0.0 < partial.comm_bytes < static.comm_bytes


def test_dropout_schedule_masks_are_subgraphs(setup):
    exp, _ = setup
    g = make_graph("er", exp.n_clients, 3.0, seed=0)
    sched = dropout_schedule(g, rounds=8, p_drop=0.5, seed=2)
    assert sched.adjs.shape == (8, g.n, g.n)
    for adj in sched.adjs:
        assert (adj <= g.adj).all()          # only removes edges
        assert (np.diag(adj) == 1.0).all()   # self link survives
        np.testing.assert_array_equal(adj, adj.T)
    assert (sched.union().adj <= g.adj).all()


# ------------------------------------------------------------------
# stacked-data batched driver (the table23 per-seed-dataset protocol)
# ------------------------------------------------------------------


SEEDS = (0, 1, 2)


def _datasets():
    return [
        make_mixture_classification(n_clients=6, n_clusters=2,
                                    n_per_client=32, dim=8, n_classes=3,
                                    seed=100 + i, noise=0.3)
        for i in range(len(SEEDS))
    ]


@pytest.mark.parametrize("method", ["fedspd", "dfl_fedavg", "dfl_fedem"])
def test_stacked_batch_matches_run_method_loop(setup, method):
    """k seeds × k datasets in ONE compile: the stacked-data batch equals
    a loop of k independent run_method calls, per client per seed."""
    exp, _ = setup
    datasets = _datasets()
    g = make_graph("er", exp.n_clients, 3.0, seed=2)
    batch = run_method_batch(method, datasets, exp, seeds=SEEDS, graph=g,
                             eval_every=100)
    assert batch[0].extras["n_compiles"] == 1
    for i, s in enumerate(SEEDS):
        solo = run_method(method, datasets[i], exp, graph=g, seed=s,
                          eval_every=100)
        np.testing.assert_allclose(batch[i].acc_per_client,
                                   solo.acc_per_client, atol=1e-6)
        np.testing.assert_allclose(batch[i].comm_bytes, solo.comm_bytes,
                                   rtol=1e-6)


def test_per_seed_graphs_batch_matches_loop(setup):
    """k seeds × k datasets × k GRAPHS in one compile: per-seed graphs ride
    the traced-adjacency axis (in_axes=0), the context wiring uses the
    union graph, and every seed still reproduces its solo run."""
    exp, _ = setup
    datasets = _datasets()
    graphs = [make_graph("er", exp.n_clients, 3.0, seed=10 + i)
              for i in range(len(SEEDS))]
    batch = run_method_batch("fedspd", datasets, exp, seeds=SEEDS,
                             graph=graphs, eval_every=100)
    assert batch[0].extras["n_compiles"] == 1
    for i, s in enumerate(SEEDS):
        solo = run_method("fedspd", datasets[i], exp, graph=graphs[i],
                          seed=s, eval_every=100)
        np.testing.assert_allclose(batch[i].acc_per_client,
                                   solo.acc_per_client, atol=1e-6)
        np.testing.assert_allclose(batch[i].comm_bytes, solo.comm_bytes,
                                   rtol=1e-6)


def test_batch_accepts_run_method_convenience_kwargs(setup):
    """run_method and run_method_batch take the same configuration: the
    kwargs route into options identically (here: the packed plane — its
    state is a single (S, N, X) leaf — and the permute wiring)."""
    exp, data = setup
    results = run_method_batch("fedspd", data, exp, seeds=SEEDS,
                               eval_every=100, param_plane=True,
                               gossip_mode="permute",
                               gossip_backend="pallas")
    assert len(results) == len(SEEDS)
    assert all(np.isfinite(r.mean_acc) for r in results)
    assert results[0].extras["n_compiles"] == 1
    # parity with the solo entry point under the identical configuration
    solo = run_method("fedspd", data, exp, seed=0, eval_every=100,
                      param_plane=True, gossip_mode="permute",
                      gossip_backend="pallas")
    np.testing.assert_allclose(results[0].acc_per_client,
                               solo.acc_per_client, atol=1e-6)


# ------------------------------------------------------------------
# validation contracts
# ------------------------------------------------------------------


def test_dynamic_scenario_requires_method_support(setup):
    exp, data = setup
    with pytest.raises(ValueError, match="dynamic"):
        run_method("dfl_fedavg", data, exp, seed=0,
                   scenario=Scenario(dropout=0.5))


def test_scenario_and_batch_validation(setup):
    exp, data = setup
    datasets = _datasets()
    with pytest.raises(ValueError, match="per-seed sequence"):
        run_method_batch("fedspd", data, exp, seeds=SEEDS,
                         scenario=Scenario(data_stack=True))
    with pytest.raises(ValueError, match="datasets for"):
        run_method_batch("fedspd", datasets[:2], exp, seeds=SEEDS)
    graphs = [make_graph("er", exp.n_clients, 3.0, seed=i)
              for i in range(len(SEEDS))]
    with pytest.raises(ValueError, match="mutually exclusive"):
        run_method_batch("fedspd", datasets, exp, seeds=SEEDS, graph=graphs,
                         scenario=Scenario(dropout=0.5))
    with pytest.raises(ValueError, match="graphs for"):
        run_method_batch("fedspd", datasets, exp, seeds=SEEDS,
                         graph=graphs[:2])
    with pytest.raises(ValueError, match="rounds, N, N"):
        Scenario(graph_schedule=np.ones((4, 3))).resolve(None, 4)
    with pytest.raises(ValueError, match="base graph"):
        Scenario(dropout=0.5).resolve(None, 4)
