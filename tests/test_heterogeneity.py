"""Client-system heterogeneity engine (experiments/heterogeneity.py).

Robustness invariants: the loop and scan engines see the identical
straggler/staleness stream (bit-parity), a timed-out or unavailable client
is charged ZERO wire bytes and its plane rows are carried bit-untouched,
staleness counters reset on successful exchange, age-decayed mixing
matrices stay row-stochastic, and the host/traced edge-drop paths share
one symmetric-mask core.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperExpConfig
from repro.core.gossip import GossipSpec, fedspd_weight_matrix, round_comm_bytes
from repro.data.synthetic import make_mixture_classification
from repro.experiments import (
    ClientSystemModel,
    RunConfig,
    Scenario,
    run_method,
    run_method_batch,
)
from repro.experiments.heterogeneity import (
    apply_client_weights,
    het_round,
    masked_client_step,
)
from repro.experiments.registry import build_context, get_method
from repro.graphs.topology import drop_edges, make_graph, symmetric_mask_drop

pytestmark = pytest.mark.robustness


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(n_clients=6, n_per_client=32, rounds=4, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=7, noise=0.3,
    )
    graph = make_graph("er", 6, 3.0, seed=0)
    return exp, data, graph


HET = ClientSystemModel(slow_fraction=0.34, slow_factor=4.0,
                        time_budget=2.0, p_unavailable=0.2,
                        staleness_gamma=0.8, seed=3)


# --------------------------------------------------------------------------
# Model validation
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs,field", [
    (dict(slow_fraction=1.5), "slow_fraction"),
    (dict(p_unavailable=-0.1), "p_unavailable"),
    (dict(markov=(1.2, 0.5)), "markov[0]"),
    (dict(markov=(0.5,)), "markov"),
    (dict(p_unavailable=0.2, markov=(0.1, 0.5)), "mutually exclusive"),
    (dict(slow_factor=0.5), "slow_factor"),
    (dict(time_budget=-1.0), "time_budget"),
    (dict(jitter=-0.5), "jitter"),
    (dict(staleness_gamma=0.0), "staleness_gamma"),
    (dict(staleness_gamma=1.5), "staleness_gamma"),
])
def test_client_system_model_validates(kwargs, field):
    with pytest.raises(ValueError, match=field.replace("[", r"\[")):
        ClientSystemModel(**kwargs)


def test_scenario_dropout_validates():
    with pytest.raises(ValueError, match="dropout"):
        Scenario(dropout=1.5)
    with pytest.raises(ValueError, match="dropout"):
        Scenario(dropout=-0.2)


def test_system_scenario_is_dynamic():
    assert Scenario(system=HET).dynamic
    assert not Scenario().dynamic


def test_resolve_speeds():
    m = ClientSystemModel(slow_fraction=0.5, slow_factor=4.0, seed=1)
    speeds = m.resolve_speeds(8)
    assert speeds.shape == (8,)
    assert (speeds == 0.25).sum() == 4 and (speeds == 1.0).sum() == 4
    # explicit speeds win and are validated
    m2 = ClientSystemModel(speed=[1.0, 0.5])
    np.testing.assert_array_equal(m2.resolve_speeds(2), [1.0, 0.5])
    with pytest.raises(ValueError, match="shape"):
        m2.resolve_speeds(3)
    with pytest.raises(ValueError, match="positive"):
        ClientSystemModel(speed=[1.0, 0.0]).resolve_speeds(2)


# --------------------------------------------------------------------------
# het_round: staleness semantics and key-derivation
# --------------------------------------------------------------------------


def test_staleness_resets_on_exchange_and_grows_offline():
    m = ClientSystemModel(staleness_gamma=0.5)
    carry = m.init_carry(3)._replace(stale=jnp.asarray([3, 5, 0], jnp.int32))
    # no straggler/availability model => everyone active: counters reset,
    # but THIS round's weight is decayed by the PRE-reset age
    carry2, w = het_round(m, jnp.ones(3), carry, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(carry2.stale), [0, 0, 0])
    np.testing.assert_allclose(np.asarray(w), [0.5 ** 3, 0.5 ** 5, 1.0])
    # everyone down => counters grow, weights zero
    m_down = ClientSystemModel(p_unavailable=1.0)
    carry3, w3 = het_round(m_down, jnp.ones(3), carry,
                           jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(carry3.stale), [4, 6, 1])
    np.testing.assert_array_equal(np.asarray(w3), [0.0, 0.0, 0.0])


def test_straggler_timeout_is_deterministic_per_speed():
    # 1/speed > budget with no jitter => ALWAYS straggles; timely client
    # never does
    m = ClientSystemModel(time_budget=2.0)
    speeds = jnp.asarray([1.0, 0.25])
    for r in range(4):
        _, w = het_round(m, speeds, m.init_carry(2),
                         jax.random.fold_in(jax.random.PRNGKey(0), r))
        np.testing.assert_array_equal(np.asarray(w), [1.0, 0.0])


def test_markov_availability_chain():
    # p_fail=0, p_recover=1: an up client stays up, a down one recovers
    m = ClientSystemModel(markov=(0.0, 1.0))
    carry = m.init_carry(2)._replace(avail=jnp.asarray([1.0, 0.0]))
    carry2, w = het_round(m, jnp.ones(2), carry, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(carry2.avail), [1.0, 1.0])
    # p_fail=1: everyone down next round
    m2 = ClientSystemModel(markov=(1.0, 0.0))
    carry3, w3 = het_round(m2, jnp.ones(2), carry2, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(w3), [0.0, 0.0])


# --------------------------------------------------------------------------
# Adjacency masking + comm accounting
# --------------------------------------------------------------------------


def test_apply_client_weights_masks_rows_and_columns():
    adj = jnp.ones((3, 3))
    w = jnp.asarray([1.0, 0.0, 0.5])
    out = np.asarray(apply_client_weights(adj, w))
    assert (out[1, :] == 0).all() and (out[:, 1] == 0).all()
    np.testing.assert_allclose(out[0], [1.0, 0.0, 0.5])


def test_decayed_weight_matrix_row_stochastic():
    g = make_graph("er", 8, 4.0, seed=2)
    spec = GossipSpec.from_graph(g)
    s = jnp.zeros(8, jnp.int32)
    w_cl = jnp.asarray([1.0, 0.9, 0.0, 0.5, 1.0, 0.0, 0.7, 1.0])
    adj = apply_client_weights(jnp.asarray(g.adj), w_cl)
    W = np.asarray(fedspd_weight_matrix(spec, s, adj=adj))
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
    assert (W >= 0).all()
    # an inactive client's row collapses to e_i: it keeps its own model
    for i in (2, 5):
        e = np.zeros(8)
        e[i] = 1.0
        np.testing.assert_array_equal(W[i], e)
    # nobody averages an inactive client in
    assert (W[:, 2][np.arange(8) != 2] == 0).all()


def test_masked_links_charge_zero_and_binarized_bytes():
    g = make_graph("er", 6, 3.0, seed=0)
    spec = GossipSpec.from_graph(g)
    s = jnp.zeros(6, jnp.int32)
    full = float(round_comm_bytes(spec, s, 100,
                                  adj=jnp.asarray(g.adj)))
    # fractional stale weights are binarized: same bytes as the 0/1 graph
    w_stale = jnp.asarray([1.0, 0.5, 0.25, 1.0, 0.9, 0.4])
    stale_adj = apply_client_weights(jnp.asarray(g.adj), w_stale)
    assert float(round_comm_bytes(spec, s, 100, adj=stale_adj)) == full
    # a fully masked client is charged zero: bytes drop by exactly its
    # (binary) links, and an all-down round charges exactly zero
    down = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0, 1.0])
    lost = 2 * float(np.asarray(g.adj)[2].sum() - 1)  # both directions
    got = float(round_comm_bytes(
        spec, s, 100, adj=apply_client_weights(jnp.asarray(g.adj), down)))
    assert got == full - lost * 100
    allz = apply_client_weights(jnp.asarray(g.adj), jnp.zeros(6))
    assert float(round_comm_bytes(spec, s, 100, adj=allz)) == 0.0


def test_inactive_plane_rows_bit_untouched(setup):
    exp, data, graph = setup
    m = get_method("fedspd")
    ctx = build_context(data, exp, graph=graph, seed=0,
                        options={"param_plane": True})
    key = jax.random.PRNGKey(0)
    state = m.init(ctx, key)
    axes = m.cohort_axes(ctx, state)
    step = masked_client_step(m.make_step(ctx), axes)
    aw = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0, 1.0])
    new, _ = jax.jit(step)(state, ctx.train, jax.random.PRNGKey(1),
                           0.05, jnp.asarray(graph.adj, jnp.float32), aw)
    old_c, new_c = np.asarray(state.centers), np.asarray(new.centers)
    old_u, new_u = np.asarray(state.u), np.asarray(new.u)
    for i in (2, 4):  # inactive: the EXACT old bits
        np.testing.assert_array_equal(new_c[:, i], old_c[:, i])
        np.testing.assert_array_equal(new_u[i], old_u[i])
    for i in (0, 1, 3, 5):  # active clients actually trained
        assert not np.array_equal(new_c[:, i], old_c[:, i])


# --------------------------------------------------------------------------
# Engine parity + whole-run accounting
# --------------------------------------------------------------------------


def _run(setup, cfg, batch=False):
    exp, data, graph = setup
    if batch:
        return run_method_batch("fedspd", data, exp, seeds=(0, 1),
                                graph=graph, cfg=cfg)
    return run_method("fedspd", data, exp, graph=graph, seed=0, cfg=cfg)


def test_loop_scan_bit_parity_heterogeneity(setup):
    base = RunConfig(param_plane=True, eval_every=2,
                     scenario=Scenario(system=HET))
    a = _run(setup, base)
    b = _run(setup, dataclasses.replace(base, scan_rounds=True))
    np.testing.assert_array_equal(a.acc_per_client, b.acc_per_client)
    np.testing.assert_array_equal(a.extras["staleness"],
                                  b.extras["staleness"])
    assert a.comm_bytes == b.comm_bytes
    assert b.extras["n_compiles"] == 1 and b.extras["n_dispatches"] == 1


def test_full_composition_one_compile(setup):
    """Stragglers + Markov availability + staleness decay + link dropout
    + cohort subsampling, batched over seeds: ONE compiled program under
    both engines, bit-identical."""
    het = ClientSystemModel(slow_fraction=0.34, time_budget=2.0,
                            markov=(0.3, 0.7), staleness_gamma=0.9, seed=5)
    base = RunConfig(param_plane=True, eval_every=2, cohort_size=4,
                     scenario=Scenario(dropout=0.2, system=het, seed=11))
    a = _run(setup, base, batch=True)
    b = _run(setup, dataclasses.replace(base, scan_rounds=True),
             batch=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.acc_per_client, y.acc_per_client)
        assert x.comm_bytes == y.comm_bytes
    assert b[0].extras["n_compiles"] == 1
    assert b[0].extras["n_dispatches"] == 1


def test_all_down_run_charges_zero_bytes(setup):
    cfg = RunConfig(param_plane=True, eval_every=10 ** 9,
                    scenario=Scenario(
                        system=ClientSystemModel(p_unavailable=1.0)))
    r = _run(setup, cfg)
    assert r.comm_bytes == 0.0 and r.wire_bytes == 0.0
    exp = setup[0]
    np.testing.assert_array_equal(r.extras["staleness"],
                                  np.full(6, exp.rounds))


def test_always_straggling_clients_never_exchange(setup):
    # explicit speeds: clients 4 and 5 can never meet the budget
    het = ClientSystemModel(speed=[1, 1, 1, 1, 0.25, 0.25],
                            time_budget=2.0)
    cfg = RunConfig(param_plane=True, eval_every=10 ** 9,
                    scenario=Scenario(system=het))
    r = _run(setup, cfg)
    exp = setup[0]
    np.testing.assert_array_equal(r.extras["staleness"][4:],
                                  [exp.rounds, exp.rounds])
    np.testing.assert_array_equal(r.extras["staleness"][:4], [0, 0, 0, 0])
    # a straggler never trained: its mixture weights are still uniform
    u = np.asarray(r.extras["u"])
    np.testing.assert_array_equal(u[4:], np.full_like(u[4:], 0.5))


def test_het_requires_dynamic_capable_method(setup):
    exp, data, graph = setup
    cfg = RunConfig(scenario=Scenario(system=HET))
    with pytest.raises(ValueError, match="dynamic"):
        run_method("local", data, exp, graph=graph, seed=0, cfg=cfg)


# --------------------------------------------------------------------------
# Shared symmetric edge-drop core
# --------------------------------------------------------------------------


def test_symmetric_mask_drop_host_traced_agree():
    g = make_graph("er", 10, 4.0, seed=3)
    rng = np.random.default_rng(0)
    u = np.triu(rng.random((10, 10)).astype(np.float32), k=1)
    u = u + u.T
    host = symmetric_mask_drop(g.adj, u, 0.4, xp=np)
    traced = np.asarray(symmetric_mask_drop(
        jnp.asarray(g.adj), jnp.asarray(u), 0.4, xp=jnp))
    np.testing.assert_array_equal(host, traced)
    assert (np.diag(host) == 1).all()
    np.testing.assert_array_equal(host, host.T)


def test_drop_edges_extremes():
    g = make_graph("er", 8, 4.0, seed=1)
    rng = np.random.default_rng(0)
    none = drop_edges(g.adj, 0.0, rng)
    np.testing.assert_array_equal(none, g.adj)
    all_ = drop_edges(g.adj, 1.0, np.random.default_rng(1))
    np.testing.assert_array_equal(all_, np.eye(8, dtype=np.float32))
