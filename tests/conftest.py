"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see the real single CPU device; mesh/sharding tests spawn
subprocesses with their own --xla_force_host_platform_device_count."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
