"""Buffer donation of the round-step state (ISSUE-3 satellite).

``make_round_step(donate=True)`` and the experiment driver jit the step
with ``donate_argnums=0``: the packed (S, N, X) plane — the dominant
allocation of every run — must be ALIASED input→output (no per-round
copy), and a donated reference must actually die (reuse raises), proving
the aliasing is real rather than cosmetic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedspd import FedSPDConfig, init_state, make_round_step
from repro.core.gossip import GossipSpec
from repro.core.packing import make_pack_spec, pack_state
from repro.data.synthetic import make_mixture_classification
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def packed_step_setup():
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=4,
        seed=0,
    )
    _, _, loss_fn, pel_fn, _ = make_classifier("mlp", KEY, 8, 4)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, 8, 4)
        return p

    fcfg = FedSPDConfig(n_clients=6, n_clusters=2, tau=1, batch=8)
    spec = GossipSpec.from_graph(make_graph("er", 6, 3.0, seed=0))
    ps = make_pack_spec(jax.eval_shape(model_init, KEY))
    state = pack_state(init_state(KEY, model_init, fcfg, 32), ps)
    step = make_round_step(loss_fn, pel_fn, spec, fcfg, pack_spec=ps,
                           donate=True)
    payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    return step, state, payload


def test_donated_plane_is_aliased_in_compiled_executable(packed_step_setup):
    """Compile-level proof of in-place update: the lowered executable must
    carry input_output_alias entries covering the donated state — in
    particular an alias whose buffer SIZE matches the (S, N, X) plane
    (6 clients × 2 clusters × X fp32), so the round's dominant buffer is
    reused, not copied."""
    step, state, payload = packed_step_setup
    compiled = step.lower(state, payload).compile()
    hlo = compiled.as_text()
    assert "input_output_alias" in hlo
    s, n, x = state.centers.shape
    plane_shape = f"f32[{s},{n},{x}]"
    # the aliased parameter list includes the full plane-shaped buffer
    alias_header = hlo.split("\n", 5)
    head = "\n".join(alias_header[:5])
    assert plane_shape in head, (plane_shape, head)


def test_second_use_of_donated_state_raises(packed_step_setup):
    """Donation is real: after the step consumes the state, the old
    reference's buffer is deleted and any further use raises."""
    step, state, payload = packed_step_setup
    new_state, _ = step(state, payload)
    jax.block_until_ready(new_state.centers)
    with pytest.raises(RuntimeError, match="deleted"):
        _ = (state.centers + 0.0).block_until_ready()
    # the returned state is live and round advanced
    assert int(new_state.round) == int(np.asarray(new_state.round))
    new2, _ = step(new_state, payload)
    jax.block_until_ready(new2.centers)


def test_driver_donation_default_and_opt_out():
    """run_method donates by default; options={"donate": False} opts out
    and reproduces the same trajectory (donation is an aliasing decision,
    never a numerical one)."""
    from repro.configs.paper_cnn import PaperExpConfig
    from repro.experiments import run_method

    exp = PaperExpConfig(
        n_clients=5, n_per_client=32, rounds=3, tau=1, batch=8,
        avg_degree=3.0, model="mlp", dim=8, n_classes=3,
    )
    data = make_mixture_classification(
        n_clients=5, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    a = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   param_plane=True)
    b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   param_plane=True, options={"donate": False})
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client, atol=1e-6)
    np.testing.assert_allclose(a.extras["u"], b.extras["u"], atol=1e-6)
