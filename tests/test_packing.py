"""Packed parameter-plane engine (core/packing.py + the flat round step).

Covers the ISSUE-2 acceptance criteria:
- pack -> unpack round-trips mixed-dtype pytrees exactly, under any batch
  prefix and under vmap;
- the packed round step matches the pytree reference within fp32
  tolerance for BOTH regimes and ALL gossip backends, including the
  DP-enabled path (clip-only parity is exact; the fused Pallas DP kernel
  matches the packed reference bit-for-bit on the same noise stream);
- the Pallas backend issues exactly ONE pallas_call per mix on the
  packed plane (vs one per leaf on the pytree path);
- the registry/runner ``param_plane`` toggle reproduces the pytree run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedspd import (
    FedSPDConfig,
    init_state,
    make_round_step,
    personalize,
)
from repro.core.gossip import GossipSpec, make_mix_fn
from repro.core.packing import (
    make_pack_spec,
    pack,
    pack_state,
    unpack,
    unpack_state,
)
from repro.data.synthetic import make_mixture_classification
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier
from repro.utils.pytree import tree_bytes

KEY = jax.random.PRNGKey(0)


def _mixed_tree(key, batch=()):
    ks = jax.random.split(key, 4)
    mk = lambda k, shape, dt: jax.random.normal(  # noqa: E731
        k, batch + shape, jnp.float32).astype(dt)
    return {
        "w32": mk(ks[0], (5, 3), jnp.float32),
        "b16": mk(ks[1], (7,), jnp.bfloat16),
        "h16": mk(ks[2], (2, 2, 2), jnp.float16),
        "scalar": mk(ks[3], (), jnp.float32),
    }


# ---------------------------------------------------------------- metadata


def test_pack_spec_static_metadata():
    tree = _mixed_tree(KEY)
    spec = make_pack_spec(tree)
    assert spec.size == 15 + 7 + 8 + 1
    assert spec.n_leaves == 4
    assert spec.offsets[0] == 0
    assert spec.offsets == tuple(np.cumsum((0,) + spec.sizes)[:-1])
    # wire accounting uses ORIGINAL dtypes, not the fp32 plane dtype
    assert spec.model_bytes == tree_bytes(tree)


def test_pack_spec_from_eval_shape():
    def model_init(k):
        p, *_ = make_classifier("mlp", k, 8, 4)
        return p

    spec = make_pack_spec(jax.eval_shape(model_init, KEY))
    params = model_init(KEY)
    plane = pack(params, spec)
    assert plane.shape == (spec.size,)
    assert spec.model_bytes == tree_bytes(params)


# --------------------------------------------------------------- roundtrip


@pytest.mark.parametrize("batch", [(), (6,), (2, 6)])
def test_pack_unpack_roundtrip_mixed_dtypes(batch):
    """fp32 plane exactly represents fp32/bf16/fp16 leaves: pack -> unpack
    is bitwise, for any leading batch prefix (model, (N,), (S, N))."""
    tree = _mixed_tree(KEY, batch)
    spec = make_pack_spec(_mixed_tree(jax.random.PRNGKey(1)))
    plane = pack(tree, spec)
    assert plane.shape == batch + (spec.size,)
    assert plane.dtype == jnp.float32
    back = unpack(plane, spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_unpack_under_vmap_and_jit():
    spec = make_pack_spec(_mixed_tree(KEY))
    trees = _mixed_tree(KEY, (3, 5))

    def through(tree):
        return unpack(pack(tree, spec), spec)

    out = jax.jit(jax.vmap(jax.vmap(through)))(trees)
    for a, b in zip(jax.tree.leaves(trees), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_rejects_mismatched_tree():
    spec = make_pack_spec(_mixed_tree(KEY))
    bad = dict(_mixed_tree(KEY), w32=jnp.zeros((4, 3)))
    with pytest.raises(ValueError, match="does not end with packed shape"):
        pack(bad, spec)
    with pytest.raises(ValueError, match="plane width"):
        unpack(jnp.zeros((3, spec.size + 1)), spec)


# ------------------------------------------------------ round-step parity


def _setup(n=6, s=2, m=48, dim=8, seed=0, model="mlp"):
    data = make_mixture_classification(
        n_clients=n, n_clusters=s, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    _, _, loss_fn, pel_fn, _ = make_classifier(model, KEY, dim, 4)

    def model_init(k):
        p, *_ = make_classifier(model, k, dim, 4)
        return p

    return data, loss_fn, pel_fn, model_init


def _run_both(regime, mode, backend, dp=(0.0, 0.0), rounds=3, n=6):
    data, loss_fn, pel_fn, model_init = _setup(n=n)
    fcfg = FedSPDConfig(
        n_clients=n, n_clusters=2, tau=2, batch=8, regime=regime,
        dp_clip=dp[0], dp_noise_multiplier=dp[1],
    )
    spec = GossipSpec.from_graph(make_graph("er", n, 3.0, seed=0), mode=mode)
    ps = make_pack_spec(jax.eval_shape(model_init, KEY))
    state = init_state(KEY, model_init, fcfg, data.points_per_client)
    step_tree = jax.jit(make_round_step(
        loss_fn, pel_fn, spec, fcfg, mix_fn=make_mix_fn(spec, backend),
    ))
    step_pack = jax.jit(make_round_step(
        loss_fn, pel_fn, spec, fcfg,
        mix_fn=make_mix_fn(spec, backend, plane=True), pack_spec=ps,
    ))
    if regime == "full":
        payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    else:
        payload = {"x": jnp.asarray(data.x[:, :8]),
                   "y": jnp.asarray(data.y[:, :8])}
    st_t, st_p = state, pack_state(state, ps)
    for _ in range(rounds):
        st_t, m_t = step_tree(st_t, payload)
        st_p, m_p = step_pack(st_p, payload)
    return st_t, m_t, st_p, m_p, ps


def _assert_state_parity(st_t, m_t, st_p, m_p, ps, atol=2e-5):
    up = unpack_state(st_p, ps)
    for a, b in zip(jax.tree.leaves(st_t.centers), jax.tree.leaves(up.centers)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)
    np.testing.assert_allclose(np.asarray(st_t.u), np.asarray(st_p.u),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(m_t["consensus"]),
                               np.asarray(m_p["consensus"]), rtol=1e-3,
                               atol=1e-6)
    # identical comm accounting (original-dtype wire bytes)
    assert float(st_t.comm_bytes) == float(st_p.comm_bytes)
    # Eq. (2) personalization parity at the API boundary
    pa, pb = personalize(st_t), personalize(st_p, ps)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


# fast lane keeps one combo per axis; the full matrix runs in the slow lane
_SLOW = pytest.mark.slow
_PARITY_CASES = [
    ("full", "dense", "reference"),
    ("stream", "dense", "pallas"),
    pytest.param("full", "permute", "reference", marks=_SLOW),
    pytest.param("full", "dense", "pallas", marks=_SLOW),
    pytest.param("stream", "dense", "reference", marks=_SLOW),
    pytest.param("stream", "permute", "reference", marks=_SLOW),
]


@pytest.mark.parametrize("regime,mode,backend", _PARITY_CASES)
def test_packed_matches_pytree_round_step(regime, mode, backend):
    """The packed (S, N, X) engine IS the pytree round step, re-expressed:
    same selections, same batches, same updates, same mixing — to fp32
    tolerance — across regimes and gossip backends."""
    st_t, m_t, st_p, m_p, ps = _run_both(regime, mode, backend)
    _assert_state_parity(st_t, m_t, st_p, m_p, ps)


@pytest.mark.parametrize("regime", ["full", pytest.param("stream", marks=_SLOW)])
def test_packed_dp_clip_parity_exact(regime):
    """DP with clipping but no noise is deterministic: the flat (N, X) L2
    clip must equal the per-leaf-summed pytree clip."""
    st_t, m_t, st_p, m_p, ps = _run_both(regime, "dense", "reference",
                                         dp=(0.5, 0.0))
    _assert_state_parity(st_t, m_t, st_p, m_p, ps)


def test_packed_dp_fused_pallas_matches_packed_reference():
    """With noise enabled the packed reference and the fused Pallas
    clip·scale+W·C kernel consume the SAME key stream and noise draw, so
    the whole trajectory must agree to fp32 tolerance."""
    data, loss_fn, pel_fn, model_init = _setup()
    fcfg = FedSPDConfig(n_clients=6, n_clusters=2, tau=2, batch=8,
                        dp_clip=0.5, dp_noise_multiplier=0.7)
    spec = GossipSpec.from_graph(make_graph("er", 6, 3.0, seed=0))
    ps = make_pack_spec(jax.eval_shape(model_init, KEY))
    st0 = pack_state(init_state(KEY, model_init, fcfg,
                                data.points_per_client), ps)
    mix_pal = make_mix_fn(spec, "pallas", plane=True)
    assert hasattr(mix_pal, "fused_dp")
    step_ref = jax.jit(make_round_step(
        loss_fn, pel_fn, spec, fcfg,
        mix_fn=make_mix_fn(spec, "reference", plane=True), pack_spec=ps,
    ))
    step_fus = jax.jit(make_round_step(
        loss_fn, pel_fn, spec, fcfg, mix_fn=mix_pal, pack_spec=ps,
    ))
    payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    sr, sf = st0, st0
    for _ in range(2):
        sr, _ = step_ref(sr, payload)
        sf, _ = step_fus(sf, payload)
    np.testing.assert_allclose(np.asarray(sr.centers), np.asarray(sf.centers),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(sr.u), np.asarray(sf.u), atol=1e-5)


# ------------------------------------------------- exactly one pallas_call


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas_call" in eqn.primitive.name:
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if type(sub).__name__ == "ClosedJaxpr":
                    n += _count_pallas_calls(sub.jaxpr)
                elif type(sub).__name__ == "Jaxpr":
                    n += _count_pallas_calls(sub)
    return n


def test_pallas_backend_single_call_on_packed_plane():
    """The whole point of the packed plane: one streaming kernel launch per
    mix over the (N, X) buffer, versus one per leaf on the pytree path."""
    _, _, _, model_init = _setup(model="conv", dim=16)
    ps = make_pack_spec(jax.eval_shape(model_init, KEY))
    n = 6
    spec = GossipSpec.from_graph(make_graph("er", n, 3.0, seed=0))
    s = jnp.zeros((n,), jnp.int32)
    plane = jnp.zeros((n, ps.size), jnp.float32)
    tree = jax.tree.map(
        lambda sd: jnp.zeros((n,) + sd.shape, sd.dtype),
        jax.eval_shape(model_init, KEY),
    )
    flat_calls = _count_pallas_calls(
        jax.make_jaxpr(make_mix_fn(spec, "pallas", plane=True))(plane, s).jaxpr
    )
    tree_calls = _count_pallas_calls(
        jax.make_jaxpr(make_mix_fn(spec, "pallas"))(tree, s).jaxpr
    )
    assert flat_calls == 1
    assert tree_calls == ps.n_leaves  # one launch per leaf on the old path


def test_packed_round_step_issues_exactly_one_pallas_call():
    """End to end: a FULL packed round on the Pallas backend contains
    exactly one pallas_call — gossip is the only kernel stage."""
    data, loss_fn, pel_fn, model_init = _setup()
    fcfg = FedSPDConfig(n_clients=6, n_clusters=2, tau=2, batch=8)
    spec = GossipSpec.from_graph(make_graph("er", 6, 3.0, seed=0))
    ps = make_pack_spec(jax.eval_shape(model_init, KEY))
    state = pack_state(init_state(KEY, model_init, fcfg,
                                  data.points_per_client), ps)
    step = make_round_step(
        loss_fn, pel_fn, spec, fcfg,
        mix_fn=make_mix_fn(spec, "pallas", plane=True), pack_spec=ps,
    )
    payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    jaxpr = jax.make_jaxpr(step)(state, payload)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1


# --------------------------------------------------- registry integration


@pytest.fixture(scope="module")
def reg_setup():
    from repro.configs.paper_cnn import PaperExpConfig

    exp = PaperExpConfig(
        n_clients=5, n_per_client=32, rounds=3, tau=1, batch=8,
        avg_degree=3.0, model="mlp", dim=8, n_classes=3,
    )
    data = make_mixture_classification(
        n_clients=5, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    return exp, data


def test_registry_param_plane_matches_pytree_run(reg_setup):
    """run_method(param_plane=True) — packed engine through the whole
    driver (seeded init, rounds, final phase, eval) — reproduces the
    pytree run of the same seed."""
    from repro.experiments import run_method

    exp, data = reg_setup
    a = run_method("fedspd", data, exp, seed=0, eval_every=100)
    b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                   param_plane=True)
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client, atol=1e-4)
    np.testing.assert_allclose(a.extras["u"], b.extras["u"], atol=1e-4)
    assert abs(a.comm_bytes - b.comm_bytes) <= 1e-6 * max(a.comm_bytes, 1.0)


@pytest.mark.slow
def test_registry_param_plane_pallas_batch(reg_setup):
    """Packed plane + Pallas backend under the multi-seed vmapped driver:
    one compile, finite results."""
    from repro.experiments import run_method_batch

    exp, data = reg_setup
    rs = run_method_batch(
        "fedspd", data, exp, seeds=(0, 1), eval_every=2,
        options={"param_plane": True, "gossip_backend": "pallas"},
    )
    assert len(rs) == 2
    assert all(np.isfinite(r.mean_acc) for r in rs)
    assert rs[0].extras["n_compiles"] == 1
