"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# skip (not error) the whole module where hypothesis isn't installed; CI
# installs it from requirements.txt
pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clustering import mixture_coefficients
from repro.core.gossip import (
    GossipSpec,
    fedspd_weight_matrix,
    mix_dense,
    mix_permute,
)
from repro.graphs.coloring import greedy_edge_coloring, permute_schedule
from repro.graphs.mixing import metropolis_weights, spectral_gap
from repro.graphs.topology import (
    Graph,
    dropout_schedule,
    make_graph,
    rewire_schedule,
)

# derandomize: examples are a deterministic function of the test, not of
# a per-run entropy source — a property that fails in CI fails everywhere
SET = settings(max_examples=25, deadline=None, derandomize=True)


def _graph(seed, n, deg):
    return make_graph("er", n, deg, seed=seed)


@given(seed=st.integers(0, 50), n=st.integers(4, 20),
       deg=st.floats(2.0, 6.0), s_seed=st.integers(0, 100))
@SET
def test_weight_matrix_always_row_stochastic(seed, n, deg, s_seed):
    g = _graph(seed, n, deg)
    spec = GossipSpec.from_graph(g)
    rng = np.random.default_rng(s_seed)
    s = jnp.asarray(rng.integers(0, 3, n))
    w = np.asarray(fedspd_weight_matrix(spec, s))
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-5)
    assert (w >= 0).all()
    assert (np.diag(w) > 0).all()


@given(seed=st.integers(0, 30), n=st.integers(4, 16), s_seed=st.integers(0, 99))
@SET
def test_permute_schedule_equals_dense_mix(seed, n, s_seed):
    """The edge-colored permutation schedule reproduces Eq. (1) exactly on
    arbitrary connected graphs and selections."""
    g = _graph(seed, n, 3.5)
    spec_d = GossipSpec.from_graph(g, mode="dense")
    spec_p = GossipSpec.from_graph(g, mode="permute")
    rng = np.random.default_rng(s_seed)
    s = jnp.asarray(rng.integers(0, 2, n))
    tree = {"w": jnp.asarray(rng.standard_normal((n, 13)), jnp.float32)}
    d = mix_dense(spec_d, tree, s)
    p = mix_permute(spec_p, tree, s)
    np.testing.assert_allclose(np.asarray(d["w"]), np.asarray(p["w"]),
                               atol=1e-4)


@given(seed=st.integers(0, 50), n=st.integers(4, 24))
@SET
def test_edge_coloring_is_proper(seed, n):
    """No vertex appears twice in one color class (valid matching)."""
    g = _graph(seed, n, 4.0)
    colors = greedy_edge_coloring(g)
    for cls in colors:
        seen = set()
        for (i, j) in cls:
            assert i not in seen and j not in seen
            seen.add(i); seen.add(j)
    # every off-diagonal edge is covered exactly once
    covered = set()
    for cls in colors:
        for (i, j) in cls:
            e = (min(i, j), max(i, j))
            assert e not in covered
            covered.add(e)
    norm = {(min(i, j), max(i, j)) for cls in colors for (i, j) in cls}
    expect = {(min(i, j), max(i, j)) for (i, j) in g.edges()}
    assert norm == expect


@given(seed=st.integers(0, 50), n=st.integers(4, 16))
@SET
def test_permutations_are_involutions(seed, n):
    """Each color class is a partner swap: p[p[i]] == i."""
    g = _graph(seed, n, 4.0)
    for p in permute_schedule(g):
        p = np.asarray(p)
        np.testing.assert_array_equal(p[p], np.arange(n))


@given(seed=st.integers(0, 30), n=st.integers(4, 16))
@SET
def test_metropolis_weights_doubly_stochastic(seed, n):
    g = _graph(seed, n, 3.0)
    w = metropolis_weights(g)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-6)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-6)
    gap = spectral_gap(w)
    assert 0.0 < gap <= 1.0 + 1e-9  # connected => positive gap


@given(m=st.integers(1, 64), s=st.integers(2, 5), seed=st.integers(0, 99))
@SET
def test_mixture_coefficients_simplex(m, s, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.integers(0, s, m))
    u = np.asarray(mixture_coefficients(z, s))
    np.testing.assert_allclose(u.sum(), 1.0, atol=1e-5)
    assert (u > 0).all()  # floored


@given(seed=st.integers(0, 99), x_width=st.integers(3, 300),
       block=st.integers(1, 64), bits=st.sampled_from(["int8", "int4"]))
@SET
def test_quant_roundtrip_bounded_by_block_scale(seed, x_width, block, bits):
    """decode(encode(x)) moves every coordinate by strictly less than one
    quantization step (the block's scale), for arbitrary widths, block
    sizes (including non-dividing, padded tails) and both bit depths."""
    from repro.comm import CommConfig, make_channel

    qmax = {"int8": 127.0, "int4": 7.0}[bits]
    ch = make_channel(CommConfig(codec=bits, block=block), x_width)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, x_width)), jnp.float32)
    x_hat, _ = ch.roundtrip(x, jax.random.PRNGKey(seed), None)
    nq = -(-x_width // block)
    xp = np.pad(np.asarray(x), [(0, 0), (0, nq * block - x_width)])
    scale = np.abs(xp).reshape(2, nq, block).max(-1) / qmax
    if bits == "int4":  # int4 ships fp16 scales; the step is the fp16 one
        scale = scale.astype(np.float16).astype(np.float32)
    bound = np.repeat(scale, block, axis=1)[:, :x_width]
    assert (np.abs(np.asarray(x_hat) - np.asarray(x)) <= bound + 1e-6).all()


@given(seed=st.integers(0, 99), x_width=st.integers(4, 64),
       k=st.integers(1, 8))
@SET
def test_error_feedback_residual_identity(seed, x_width, k):
    """EF invariant for the biased top-k codec: after every channel use,
    residual + transmitted == message (nothing is ever lost, only
    delayed) — the property that keeps compressed gossip unbiased over
    rounds."""
    from repro.comm import CommConfig, make_channel

    ch = make_channel(
        CommConfig(codec="topk", k=min(k, x_width), error_feedback=True),
        x_width,
    )
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, x_width)), jnp.float32)
    ef = ch.init_residual((3,))
    for t in range(3):
        ef_prev = ef
        x_hat, ef = ch.roundtrip(x, jax.random.PRNGKey(t), ef)
        np.testing.assert_allclose(np.asarray(ef + x_hat),
                                   np.asarray(x + ef_prev), atol=1e-5)


@given(kind=st.sampled_from(["er", "ba", "rgg"]), seed=st.integers(0, 50),
       n=st.integers(4, 16), rounds=st.integers(1, 6),
       p_rewire=st.floats(0.0, 0.7))
@SET
def test_rewire_schedule_graphs_always_valid(kind, seed, n, rounds, p_rewire):
    """Every graph a rewire schedule samples — any kind, any rewiring rate —
    is a valid client topology: symmetric, diag == 1, CONNECTED (the
    paper's Assumption 5.7 needs connectivity every round), and the union
    graph covers every scheduled edge (the static permute/ppermute
    machinery is built from it)."""
    sched = rewire_schedule(kind, n, 3.0, rounds, p_rewire=p_rewire,
                            seed=seed)
    assert sched.adjs.shape == (rounds, n, n)
    for t in range(rounds):
        adj = sched.adjs[t]
        np.testing.assert_array_equal(adj, adj.T)
        np.testing.assert_array_equal(np.diag(adj), 1.0)
        assert set(np.unique(adj)) <= {0.0, 1.0}
        assert Graph(adj).is_connected()
        assert (adj <= sched.union().adj).all()


@given(kind=st.sampled_from(["er", "ba", "rgg"]), seed=st.integers(0, 50),
       n=st.integers(4, 16), rounds=st.integers(1, 6),
       p_drop=st.floats(0.0, 1.0))
@SET
def test_dropout_schedule_rows_renormalize(kind, seed, n, rounds, p_drop):
    """Bernoulli link-failure masks always renormalize into a valid mixing
    matrix — exactly what fedspd_weight_matrix does with the traced
    adjacency: rows sum to 1 (the diagonal survives any dropout), entries
    stay nonnegative, and connected draws keep a positive spectral gap
    (self-loops make the chain aperiodic)."""
    g = make_graph(kind, n, 3.0, seed=seed)
    sched = dropout_schedule(g, rounds, p_drop, seed=seed + 1)
    for t in range(rounds):
        adj = sched.adjs[t]
        np.testing.assert_array_equal(adj, adj.T)
        np.testing.assert_array_equal(np.diag(adj), 1.0)
        assert (adj <= g.adj).all()  # masks only remove edges
        w = adj / adj.sum(axis=1, keepdims=True)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
        assert (w >= 0).all()
        if Graph(adj).is_connected():
            assert spectral_gap(w) > 0.0


@given(seed=st.integers(0, 40), n=st.integers(5, 14),
       gamma=st.floats(0.5, 1.0), age_seed=st.integers(0, 99))
@SET
@pytest.mark.robustness
def test_age_decayed_weight_matrix_keeps_gap(seed, n, gamma, age_seed):
    """Stale-gossip decay (experiments/heterogeneity.py): with arbitrary
    staleness ages and an arbitrary active subset, the decayed mixing
    matrix stays row-stochastic, inactive clients collapse to e_i rows,
    and the minor over ACTIVE clients keeps a positive spectral gap
    whenever the surviving subgraph is connected (self-loops make the
    weighted chain aperiodic)."""
    from repro.experiments.heterogeneity import apply_client_weights

    g = _graph(seed, n, 4.0)
    rng = np.random.default_rng(age_seed)
    stale = rng.integers(0, 6, n)
    active = rng.random(n) < 0.8
    active[rng.integers(n)] = True  # at least one active client
    w_cl = jnp.asarray(np.where(active, gamma ** stale, 0.0), jnp.float32)
    adj = apply_client_weights(jnp.asarray(g.adj, jnp.float32), w_cl)
    spec = GossipSpec.from_graph(g)
    W = np.asarray(
        fedspd_weight_matrix(spec, jnp.zeros(n, jnp.int32), adj=adj))
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-5)
    assert (W >= 0).all()
    idx = np.nonzero(active)[0]
    off = np.nonzero(~active)[0]
    for i in off:  # an offline client keeps exactly its own model
        e = np.zeros(n)
        e[i] = 1.0
        np.testing.assert_array_equal(W[i], e)
    if off.size:  # and nobody averages one in
        assert (W[np.ix_(idx, off)] == 0).all()
    sub = g.adj[np.ix_(idx, idx)]
    if idx.size >= 2 and Graph(sub).is_connected():
        # active rows are supported on active columns only, so the minor
        # is itself row-stochastic
        W_sub = W[np.ix_(idx, idx)]
        np.testing.assert_allclose(W_sub.sum(axis=1), 1.0, atol=1e-5)
        assert spectral_gap(W_sub) > 1e-6


@given(seed=st.integers(0, 99), n=st.integers(2, 8), x=st.integers(8, 160),
       density=st.floats(0.05, 0.95), prune=st.floats(0.0, 0.9),
       regrow=st.sampled_from(["rigl", "random"]))
@SET
def test_sparse_update_preserves_density_exactly(seed, n, x, density, prune,
                                                 regrow):
    """DisPFL invariant (core/sparse): init masks carry EXACTLY k_active
    ones per client row, and a RigL prune/regrow pass preserves that count
    exactly — by static construction, not in expectation — for arbitrary
    densities, prune rates, regrow modes, weights, and gradients."""
    from repro.core.sparse import SparseConfig, init_masks, rigl_update

    cfg = SparseConfig(density=density, prune_rate=prune, regrow=regrow)
    k = cfg.k_active(x)
    key = jax.random.PRNGKey(seed)
    mask = init_masks(key, n, x, cfg)
    assert set(np.unique(np.asarray(mask))) <= {0.0, 1.0}
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), float(k))
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((n, x)), jnp.float32) * mask
    g = jnp.asarray(rng.standard_normal((n, x)), jnp.float32)
    new = rigl_update(mask, w, g, jax.random.fold_in(key, 1), cfg)
    assert set(np.unique(np.asarray(new))) <= {0.0, 1.0}
    np.testing.assert_array_equal(np.asarray(new.sum(-1)), float(k))


@given(seed=st.integers(0, 99), n=st.integers(2, 6), x=st.integers(8, 120),
       density=st.floats(0.1, 0.9), prune=st.floats(0.05, 0.9),
       regrow=st.sampled_from(["rigl", "random"]))
@SET
def test_sparse_regrow_disjoint_from_pruned(seed, n, x, density, prune,
                                            regrow):
    """Within ONE RigL update, the regrown support never intersects the
    pruned support (regrow scores are restricted to pre-update inactive
    coordinates), and exactly n_prune coordinates leave = enter per row."""
    from repro.core.sparse import SparseConfig, init_masks, rigl_update

    cfg = SparseConfig(density=density, prune_rate=prune, regrow=regrow)
    key = jax.random.PRNGKey(seed)
    mask = init_masks(key, n, x, cfg)
    rng = np.random.default_rng(seed + 1)
    w = jnp.asarray(rng.standard_normal((n, x)), jnp.float32) * mask
    g = jnp.asarray(rng.standard_normal((n, x)), jnp.float32)
    new = np.asarray(rigl_update(mask, w, g, jax.random.fold_in(key, 1),
                                 cfg))
    old = np.asarray(mask)
    pruned = (old == 1.0) & (new == 0.0)
    grown = (old == 0.0) & (new == 1.0)
    n_prune = cfg.n_prune(x)
    np.testing.assert_array_equal(pruned.sum(-1), n_prune)
    np.testing.assert_array_equal(grown.sum(-1), n_prune)
    assert not (pruned & grown).any()
    # every regrown coordinate was inactive BEFORE the update
    assert (old[grown] == 0.0).all()


@given(seed=st.integers(0, 60), n=st.integers(4, 10), x=st.integers(4, 48),
       density=st.floats(0.1, 0.9), s_seed=st.integers(0, 99))
@SET
def test_masked_mixing_row_stochastic_on_active_support(seed, n, x, density,
                                                        s_seed):
    """The masked consensus mix (core/fedspd.exchange_sparse math) is
    row-stochastic ON THE ACTIVE SUPPORT: mixing the all-ones masked
    inputs returns exactly 1 on every active coordinate with a live
    denominator, and arbitrary masked inputs stay inside the per-
    coordinate convex hull of the contributing active values."""
    from repro.core.sparse import SparseConfig, init_masks

    g = _graph(seed, n, 4.0)
    spec = GossipSpec.from_graph(g)
    rng = np.random.default_rng(s_seed)
    s = jnp.asarray(rng.integers(0, 2, n))
    w = np.asarray(fedspd_weight_matrix(spec, s))
    cfg = SparseConfig(density=density)
    m = np.asarray(init_masks(jax.random.PRNGKey(seed), n, x, cfg))

    def masked_mix(v):
        num = w @ (m * v)
        den = w @ m
        return np.where((m > 0) & (den > 0),
                        num / np.maximum(den, 1e-12), m * v), den

    ones, den = masked_mix(np.ones((n, x), np.float32))
    defined = (m > 0) & (den > 0)
    np.testing.assert_allclose(ones[defined], 1.0, atol=1e-5)
    # diag(W) > 0 means every active coordinate has a live denominator
    assert (den[m > 0] > 0).all()
    v = rng.standard_normal((n, x)).astype(np.float32)
    out, _ = masked_mix(v)
    # dead coordinates contribute to neither numerator nor denominator, so
    # the hull is over the ACTIVE values of each column only
    lo = np.min(np.where(m > 0, v, np.inf), axis=0)
    hi = np.max(np.where(m > 0, v, -np.inf), axis=0)
    cols = np.nonzero(defined)[1]
    assert (out[defined] <= hi[cols] + 1e-5).all()
    assert (out[defined] >= lo[cols] - 1e-5).all()


@given(seed=st.integers(0, 99), n=st.integers(3, 12))
@SET
def test_mix_preserves_convex_hull(seed, n):
    """Row-stochastic mixing keeps every client inside the hull of inputs:
    per-coordinate min/max bounds are preserved."""
    g = _graph(seed, n, 3.0)
    spec = GossipSpec.from_graph(g)
    rng = np.random.default_rng(seed)
    s = jnp.zeros((n,), jnp.int32)
    x = jnp.asarray(rng.standard_normal((n, 9)), jnp.float32)
    out = np.asarray(mix_dense(spec, {"w": x}, s)["w"])
    assert (out.max(0) <= np.asarray(x).max(0) + 1e-5).all()
    assert (out.min(0) >= np.asarray(x).min(0) - 1e-5).all()
