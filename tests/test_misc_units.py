"""Unit tests: optimizers, schedules, checkpointing, pipeline, topology,
synthetic data, pytree utils."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import (
    sample_cluster_batch_indices,
)
from repro.data.synthetic import make_mixture_classification, make_mixture_tokens
from repro.graphs.topology import make_graph, pod_aware, rewire
from repro.optim.sgd import adamw, clip_by_global_norm, momentum, sgd
from repro.utils.pytree import tree_sq_norm, tree_weighted_sum


def test_optimizers_descend_quadratic():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for opt in (sgd(), momentum(), adamw()):
        p = {"w": jnp.zeros((4,))}
        st = opt.init(p)
        g = jax.grad(loss)
        for _ in range(200):
            p, st = opt.update(g(p), st, p, 0.05)
        assert float(loss(p)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((2,), -10.0)}
    c = clip_by_global_norm(g, 1.0)
    total = float(jnp.sqrt(tree_sq_norm(c)))
    assert abs(total - 1.0) < 1e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    path = str(tmp_path / "x.npz")
    ckpt.save(path, tree,
              manifest=ckpt.CkptManifest(kind="checkpoint",
                                         extra={"round": 7}))
    back, meta = ckpt.restore(path, tree)
    assert meta.kind == "checkpoint" and meta.extra["round"] == 7
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((2,))}
    path = str(tmp_path / "x.npz")
    ckpt.save(path, tree)
    with pytest.raises(ValueError):
        ckpt.restore(path, {"a": jnp.ones((3,))})


def test_cluster_conditional_sampling():
    key = jax.random.PRNGKey(0)
    z = jnp.array([0, 0, 1, 1, 1, 1, 0, 1])
    idx = sample_cluster_batch_indices(key, z, jnp.asarray(1), 64)
    assert set(np.asarray(z)[np.asarray(idx)]) == {1}
    # empty-cluster fallback: uniform over all points
    idx2 = sample_cluster_batch_indices(key, jnp.zeros((8,), jnp.int32),
                                        jnp.asarray(1), 64)
    assert idx2.shape == (64,)


def test_topologies_connected():
    for kind in ("er", "ba", "rgg", "ring"):
        g = make_graph(kind, 20, 4.0, seed=0)
        assert g.is_connected(), kind
        assert (np.diag(g.adj) == 1).all()  # augmented


def test_pod_aware_has_bridges():
    g = pod_aware(8, 2, seed=0)
    assert g.is_connected()
    cross = g.adj[:8, 8:].sum()
    intra = g.adj[:8, :8].sum() - 8
    assert 0 < cross < intra  # sparse bridges, dense intra


def test_rewire_keeps_connectivity_and_degree():
    g = make_graph("er", 24, 5.0, seed=1)
    g2 = rewire(g, 0.3, seed=2)
    assert g2.is_connected()
    assert abs(g2.avg_degree - g.avg_degree) < 2.5


def test_mixture_data_fractions():
    d = make_mixture_classification(n_clients=12, n_per_client=100, seed=0)
    assert d.x.shape[:2] == (12, 100)
    # per-client mixes in [0.1, 0.9]
    assert (d.mix_true > 0.05).all() and (d.mix_true < 0.95).all()
    np.testing.assert_allclose(d.mix_true.sum(-1), 1.0, atol=1e-6)
    # z_true consistent with mix
    frac = (d.z_true == 1).mean(axis=1)
    np.testing.assert_allclose(frac, d.mix_true[:, 1], atol=0.02)


def test_mixture_tokens_distinct_chains():
    pool = make_mixture_tokens(n_clients=4, docs_per_client=8, seq_len=64,
                               vocab=64, seed=0)
    assert pool["tokens"].shape == (4, 8, 64)
    # bigram stats differ across clusters
    t, z = pool["tokens"], pool["z_true"]
    def bigrams(sel):
        docs = t[z == sel]
        pairs = np.stack([docs[:, :-1].ravel(), docs[:, 1:].ravel()])
        h = np.zeros((64, 64))
        np.add.at(h, (pairs[0], pairs[1]), 1)
        return h / h.sum()
    d = np.abs(bigrams(0) - bigrams(1)).sum()
    assert d > 0.5


def test_tree_weighted_sum():
    trees = {"w": jnp.stack([jnp.ones((3,)), 3 * jnp.ones((3,))])}
    out = tree_weighted_sum(trees, jnp.array([0.25, 0.75]))
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5 * np.ones(3), atol=1e-6)


def test_er_graph_is_actually_sparse():
    """Regression: np.triu(u)<p once made every ER graph complete."""
    g = make_graph("er", 20, 5.0, seed=0)
    assert g.avg_degree < 9.0, g.avg_degree
    g2 = make_graph("er", 100, 6.0, seed=1)
    assert 4.0 < g2.avg_degree < 8.5, g2.avg_degree
