"""The shard_map ``ppermute`` gossip backend (MIX_BACKENDS third entry).

The edge-colored collective schedule from launch/steps.py is selectable
from the registry path via ``gossip_backend="ppermute"``. It needs one
device per client, so the functional tests run in subprocesses with
``--xla_force_host_platform_device_count`` (conftest.py keeps the main
process on the real single CPU device); the fast lane covers the
selector's error contracts.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.gossip import MIX_BACKENDS, GossipSpec, make_mix_fn
from repro.graphs.topology import make_graph

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 6, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_ppermute_is_registered_backend():
    assert MIX_BACKENDS == ("reference", "pallas", "ppermute")


def test_ppermute_needs_one_device_per_client():
    spec = GossipSpec.from_graph(make_graph("er", 64, 3.0, seed=0))
    with pytest.raises(RuntimeError, match="one device per client"):
        make_mix_fn(spec, backend="ppermute")


def test_ppermute_rejects_cos_alignment():
    spec = GossipSpec.from_graph(make_graph("er", 4, 2.0, seed=0),
                                 cos_align_threshold=0.5)
    with pytest.raises(ValueError, match="cosine-alignment"):
        make_mix_fn(spec, backend="ppermute")


@pytest.mark.slow
def test_ppermute_mix_matches_dense_reference():
    """One collective permute per color class reproduces Eq. (1) exactly —
    for pytree AND packed-plane inputs."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.gossip import GossipSpec, make_mix_fn, mix
        from repro.graphs.topology import make_graph

        g = make_graph("er", 6, 3.0, seed=0)
        spec = GossipSpec.from_graph(g, mode="permute")
        dense = GossipSpec.from_graph(g, mode="dense")
        key = jax.random.PRNGKey(1)
        tree = {"a": jax.random.normal(key, (6, 5, 3)),
                "b": jax.random.normal(key, (6, 17))}
        s = jax.random.randint(key, (6,), 0, 2)
        pp = jax.jit(make_mix_fn(spec, "ppermute"))
        out = pp(tree, s)
        want = mix(dense, tree, s)
        for k in tree:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(want[k]), atol=1e-5)
        plane = jax.random.normal(key, (6, 37))
        np.testing.assert_allclose(np.asarray(pp(plane, s)),
                                   np.asarray(mix(dense, plane, s)),
                                   atol=1e-5)
        print("ppermute parity OK")
    """))


@pytest.mark.slow
def test_sharded_plane_train_step_matches_single_device():
    """The multi-host path end to end: the packed (S, N, X) plane sharded
    over an (N, 1) mesh's client rows (launch/sharding.shard_plane_state),
    gossip as the edge-colored ppermute schedule, step jitted with the
    state DONATED — must reproduce the single-device reference round."""
    print(_run("""
        import types
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fedspd import FedSPDConfig, init_state, make_round_step
        from repro.core.gossip import GossipSpec
        from repro.core.packing import make_pack_spec, pack_state
        from repro.data.synthetic import make_mixture_classification
        from repro.graphs.topology import make_graph
        from repro.launch.sharding import shard_plane_state
        from repro.launch.steps import make_fedspd_train_step
        from repro.models.smallnets import make_classifier

        n = 6
        data = make_mixture_classification(n_clients=n, n_clusters=2,
                                           n_per_client=32, dim=8,
                                           n_classes=4, seed=0)
        key = jax.random.PRNGKey(0)
        _, _, loss_fn, pel_fn, _ = make_classifier("mlp", key, 8, 4)
        def model_init(k):
            p, *_ = make_classifier("mlp", k, 8, 4)
            return p
        bundle = types.SimpleNamespace(init=model_init, loss=loss_fn,
                                       per_example_loss=pel_fn)
        fcfg = FedSPDConfig(n_clients=n, n_clusters=2, tau=1, batch=8)
        gossip = GossipSpec.from_graph(make_graph("er", n, 3.0, seed=0))
        ps = make_pack_spec(jax.eval_shape(model_init, key))
        state0 = pack_state(init_state(key, model_init, fcfg, 32), ps)
        payload = {"inputs": jnp.asarray(data.x),
                   "targets": jnp.asarray(data.y)}

        # reference: single-device packed round (no mesh)
        ref_step = make_round_step(loss_fn, pel_fn, gossip, fcfg,
                                   pack_spec=ps)
        ref, _ = jax.jit(ref_step)(state0, payload)

        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:n]).reshape(n, 1), ("data", "model"))
        step = make_fedspd_train_step(bundle, gossip, fcfg, pack_spec=ps,
                                      mesh=mesh, donate=True)
        sh_state = shard_plane_state(
            pack_state(init_state(key, model_init, fcfg, 32), ps), mesh)
        out, _ = step(sh_state, payload)
        np.testing.assert_allclose(np.asarray(out.centers),
                                   np.asarray(ref.centers), atol=2e-5)
        np.testing.assert_allclose(np.asarray(out.u), np.asarray(ref.u),
                                   atol=1e-5)
        # donation is live on the sharded path too
        try:
            (sh_state.centers + 0.0).block_until_ready()
            raise SystemExit("donated sharded state still alive")
        except RuntimeError:
            pass
        print("sharded plane train step parity + donation OK")
    """))


@pytest.mark.slow
def test_ppermute_registry_round_trip():
    """gossip_backend="ppermute" resolves through the registry/driver and
    reproduces the reference run (ROADMAP open item closed)."""
    print(_run("""
        import numpy as np
        from repro.configs.paper_cnn import PaperExpConfig
        from repro.data.synthetic import make_mixture_classification
        from repro.experiments import run_method

        exp = PaperExpConfig(n_clients=5, n_per_client=32, rounds=3, tau=1,
                             batch=8, avg_degree=3.0, model="mlp", dim=8,
                             n_classes=3)
        data = make_mixture_classification(n_clients=5, n_clusters=2,
                                           n_per_client=32, dim=8,
                                           n_classes=3, seed=0, noise=0.3)
        a = run_method("fedspd", data, exp, seed=0, eval_every=100,
                       gossip_mode="permute")
        b = run_method("fedspd", data, exp, seed=0, eval_every=100,
                       gossip_mode="permute", gossip_backend="ppermute")
        np.testing.assert_allclose(a.acc_per_client, b.acc_per_client,
                                   atol=1e-4)
        np.testing.assert_allclose(a.extras["u"], b.extras["u"], atol=1e-4)
        print("registry ppermute round-trip OK", a.mean_acc, b.mean_acc)
    """, devices=5))
