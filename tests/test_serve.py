"""Mixture-serving subsystem: the PR-8 tentpole + API-redesign satellites.

Covers: ServeConfig resolve-time validation; the einsum-over-plane
personalized apply matching materialized per-user pytrees at atol=1e-6
across three model families; single-compile/single-dispatch assertions on
the serve step; the int4 bit-packed fused-kernel serve path; servable
artifacts whose quantized plane bytes equal ``wire_model_bytes`` exactly;
the typed CkptManifest (hard errors naming fields, legacy-blob reader);
the train→export→serve end-to-end loop; and the AST call-site guard that
no repo caller still uses the deprecated serving surface.
"""
import ast
import pathlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.comm.codecs import Channel, CommConfig
from repro.configs.base import get_smoke_config
from repro.configs.paper_cnn import PaperExpConfig
from repro.core.packing import make_pack_spec, pack, unpack
from repro.data.synthetic import make_mixture_classification
from repro.experiments import RunConfig, export_run, run_method
from repro.models.registry import build_model
from repro.models.smallnets import make_classifier
from repro.serve import (
    ClusterPlaneServer,
    ServeConfig,
    load_servable,
    save_servable,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------------------------
# ServeConfig.resolve validation
# ------------------------------------------------------------------


def test_serve_config_defaults_resolve():
    cfg = ServeConfig().resolve()
    assert cfg.arch == "olmo-1b" and cfg.mixture is None


@pytest.mark.parametrize("bad,match", [
    (dict(arch="gpt-17"), "unknown arch"),
    (dict(batch=0), "batch"),
    (dict(gen=-1), "gen"),
    (dict(temperature=-0.5), "temperature"),
    (dict(codec="zip"), "shipping format"),
    (dict(codec="int4", qblock=15), "even qblock"),
    (dict(client=0, mixture=(0.5, 0.5)), "exclusive"),
    (dict(client=-2), "non-negative"),
    (dict(mixture=np.ones((3, 2, 2))), r"\(S,\) or \(B, S\)"),
    (dict(mixture=(0.5, -0.5)), "non-negative"),
    (dict(mixture=(0.0, 0.0)), "positive mass"),
])
def test_serve_config_rejects_bad_fields(bad, match):
    with pytest.raises(ValueError, match=match):
        ServeConfig(**bad).resolve()


def test_serve_config_audio_unsupported():
    with pytest.raises(NotImplementedError, match="audio"):
        ServeConfig(arch="whisper-base").resolve()


def test_serve_config_normalizes_mixture_rows():
    cfg = ServeConfig(batch=2, mixture=[[2.0, 2.0], [1.0, 3.0]]).resolve()
    np.testing.assert_allclose(cfg.mixture,
                               [[0.5, 0.5], [0.25, 0.75]], atol=1e-7)


def test_serve_config_is_frozen():
    with pytest.raises(Exception):
        ServeConfig().batch = 8


def test_request_mixture_sources():
    cfg = ServeConfig(batch=3, mixture=(0.25, 0.75)).resolve()
    u = cfg.request_mixture(2)
    assert u.shape == (3, 2) and np.allclose(u[0], [0.25, 0.75])
    table = np.asarray([[0.9, 0.1], [0.2, 0.8]], np.float32)
    u = ServeConfig(batch=2, client=1).resolve().request_mixture(2, table)
    assert np.allclose(u, [[0.2, 0.8]] * 2)
    with pytest.raises(ValueError, match="out of range"):
        ServeConfig(batch=2, client=5).resolve().request_mixture(2, table)
    with pytest.raises(ValueError, match="u table"):
        ServeConfig(batch=2, client=0).resolve().request_mixture(2, None)
    # uniform default, and a cluster-count mismatch is named
    assert np.allclose(ServeConfig(batch=2).resolve().request_mixture(4),
                       0.25)
    with pytest.raises(ValueError, match="clusters"):
        ServeConfig(batch=2, mixture=(1.0, 0.0)).resolve().request_mixture(3)


# ------------------------------------------------------------------
# einsum-over-plane == materialized per-user pytrees (atol=1e-6), 3 archs
# ------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-370m", "zamba2-1.2b"])
def test_personalized_forward_matches_materialized(arch):
    """Eq. (2) served as u @ plane (then unpack) must equal the per-user
    weighted pytree sum to float accuracy, for dense/ssm/hybrid."""
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg, attn_mode="ref")
    key = jax.random.PRNGKey(0)
    spec = make_pack_spec(jax.eval_shape(bundle.init, key))
    s, b, lp = 2, 3, 8
    plane = jnp.stack([pack(bundle.init(jax.random.PRNGKey(i)), spec)
                       for i in range(s)])
    u = jnp.asarray(np.random.default_rng(0).dirichlet(
        np.ones(s), size=b).astype(np.float32))
    prompts = jax.random.randint(key, (b, lp), 0, cfg.vocab, jnp.int32)

    server = ClusterPlaneServer(spec, plane=plane, bundle=bundle)
    params_b = server.personalized(u)       # leaves (B, ...)

    clusters = [unpack(plane[i], spec) for i in range(s)]
    for i in range(b):
        # materialized per-user model: Σ_s u_is · c_s, leaf by leaf
        mat = jax.tree.map(
            lambda *ls: jnp.tensordot(u[i], jnp.stack(ls), axes=1),
            *clusters)
        got = jax.tree.map(lambda l: l[i], params_b)
        for a, c in zip(jax.tree.leaves(mat), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(c), np.asarray(a),
                                       atol=1e-6)
        logits_mat, _ = bundle.forward(mat, {"tokens": prompts[i][None]})
        logits_got, _ = bundle.forward(got, {"tokens": prompts[i][None]})
        np.testing.assert_allclose(np.asarray(logits_got),
                                   np.asarray(logits_mat), atol=1e-6)


# ------------------------------------------------------------------
# one-compile serve step + dispatch accounting
# ------------------------------------------------------------------


def _mlp_plane(s=3, dim=16, nc=4, seed=0):
    key = jax.random.PRNGKey(seed)
    _, apply, *_ = make_classifier("mlp", key, dim, nc)

    def model_init(k):
        return make_classifier("mlp", k, dim, nc)[0]

    spec = make_pack_spec(jax.eval_shape(model_init, key))
    plane = jnp.stack([pack(model_init(jax.random.PRNGKey(seed + i)), spec)
                       for i in range(s)])
    return spec, plane, apply


def test_serve_step_compiles_once_dispatches_per_call():
    spec, plane, apply = _mlp_plane()
    server = ClusterPlaneServer(spec, plane=plane, apply_fn=apply)
    rng = np.random.default_rng(0)
    u = rng.dirichlet(np.ones(3), size=5).astype(np.float32)
    x = rng.normal(size=(5, 16)).astype(np.float32)
    server.predict(u, x)
    assert server.n_compiles == 1 and server.n_dispatches == 1
    server.predict(u, x)   # same shapes: no recompile, one more dispatch
    assert server.n_compiles == 1 and server.n_dispatches == 2


def test_generate_single_compile_and_matches_materialized():
    """The LM serve step is ONE compiled program whose greedy tokens equal
    serving each user's materialized model separately."""
    cfg = get_smoke_config("olmo-1b")
    bundle = build_model(cfg, attn_mode="ref")
    key = jax.random.PRNGKey(0)
    spec = make_pack_spec(jax.eval_shape(bundle.init, key))
    s, b, lp, gen = 2, 3, 8, 4
    plane = jnp.stack([pack(bundle.init(jax.random.PRNGKey(i)), spec)
                       for i in range(s)])
    u = jnp.asarray(np.random.default_rng(1).dirichlet(
        np.ones(s), size=b).astype(np.float32))
    prompts = jax.random.randint(key, (b, lp), 0, cfg.vocab, jnp.int32)

    server = ClusterPlaneServer(spec, plane=plane, bundle=bundle)
    toks = server.generate(u, prompts, gen=gen)
    assert toks.shape == (b, gen)
    assert server.n_compiles == 1 and server.n_dispatches == 1
    assert jnp.array_equal(server.generate(u, prompts, gen=gen), toks)
    assert server.n_compiles == 1 and server.n_dispatches == 2

    # per-user materialized reference: single-cluster plane per user
    for i in range(b):
        one = ClusterPlaneServer(
            spec, plane=(u[i] @ plane)[None, :], bundle=bundle)
        ref = one.generate(jnp.ones((1, 1)), prompts[i][None], gen=gen)
        np.testing.assert_array_equal(np.asarray(toks[i]),
                                      np.asarray(ref[0]))


def test_quantized_serve_paths_match_their_decode():
    """int8 (fused dequant kernel) and int4 (bit-packed fused kernel)
    serving equal the explicit decode→einsum reference bit-for-bit."""
    spec, plane, apply = _mlp_plane()
    qb = 16
    rng = np.random.default_rng(2)
    u = rng.dirichlet(np.ones(3), size=4).astype(np.float32)
    x = rng.normal(size=(4, 16)).astype(np.float32)
    ch = Channel(CommConfig(codec="int4", block=qb), spec.size)
    enc = ch.encode(plane, jax.random.PRNGKey(3), rounding="nearest")
    dec = (enc["q"].astype(jnp.float32)
           * jnp.repeat(enc["scale"], qb, axis=1))[:, :spec.size]
    ref = jnp.stack([apply(unpack(jnp.asarray(u[i]) @ dec, spec),
                           x[i][None])[0] for i in range(4)])
    from repro.comm.codecs import int4_pack

    srv8 = ClusterPlaneServer(spec, codec="int8", qblock=qb,
                              plane_q=enc["q"], plane_scale=enc["scale"],
                              apply_fn=apply)
    srv4 = ClusterPlaneServer(spec, codec="int4", qblock=qb,
                              plane_packed=int4_pack(enc["q"]),
                              plane_scale=enc["scale"], apply_fn=apply)
    for srv in (srv8, srv4):
        out = srv.predict(u, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)
        assert srv.n_compiles == 1


# ------------------------------------------------------------------
# servable artifacts: wire-exact bytes, digest guard, round trips
# ------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["fp32", "int8", "int4"])
def test_servable_roundtrip_and_wire_exact_bytes(tmp_path, codec):
    spec, plane, apply = _mlp_plane()
    qb = 16
    path = str(tmp_path / f"plane_{codec}.npz")
    ut = np.random.default_rng(0).dirichlet(np.ones(3), size=7)
    man = save_servable(path, plane, spec, arch="mlp", u=ut, codec=codec,
                        qblock=qb)
    assert man.kind == "servable" and man.pack_digest == spec.digest
    art = load_servable(path, spec)
    assert art.n_clusters == 3
    np.testing.assert_allclose(art.u_table, ut, atol=1e-7)
    if codec == "fp32":
        np.testing.assert_array_equal(art.plane, np.asarray(plane))
    else:
        # the stored plane is EXACTLY wire_model_bytes per cluster row
        ch = Channel(CommConfig(codec=codec, block=qb), spec.size)
        with np.load(path) as data:
            wire_key = [k for k in data.files if "plane_wire" in k]
            assert len(wire_key) == 1
            assert data[wire_key[0]].nbytes == 3 * ch.wire_model_bytes
        # and decodes bit-identically to a fresh nearest-rounding encode
        enc = ch.encode(plane, jax.random.PRNGKey(0), rounding="nearest")
        np.testing.assert_array_equal(art.plane_q, np.asarray(enc["q"]))
        np.testing.assert_array_equal(art.plane_scale,
                                      np.asarray(enc["scale"]))


def test_servable_refuses_wrong_pack_digest(tmp_path):
    spec, plane, _ = _mlp_plane()
    path = str(tmp_path / "plane.npz")
    save_servable(path, plane, spec, arch="mlp")
    other = make_pack_spec(make_classifier(
        "linear", jax.random.PRNGKey(0), 16, 4)[0])
    with pytest.raises(ValueError, match="pack_digest"):
        load_servable(path, other)


def test_servable_refuses_non_servable_kind(tmp_path):
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, {"a": np.ones(3)},
              manifest=ckpt.CkptManifest(kind="checkpoint"))
    with pytest.raises(ValueError, match="kind"):
        load_servable(path)


# ------------------------------------------------------------------
# train → export → serve end-to-end (subsumes examples/serve_personalized)
# ------------------------------------------------------------------


def test_train_export_serve_end_to_end(tmp_path):
    exp = PaperExpConfig(n_clients=5, n_per_client=32, rounds=3, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=5, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    res = run_method(
        "fedspd", data, exp,
        cfg=RunConfig(param_plane=True, eval_every=100,
                      options={"keep_state": True}))
    path = str(tmp_path / "servable.npz")
    man = export_run(res, path, arch="mlp", codec="int4", qblock=16)
    assert man.n_clients == 5 and man.n_clusters == 2

    _, apply, *_ = make_classifier("mlp", jax.random.PRNGKey(0), 8, 3)
    spec = make_pack_spec(make_classifier(
        "mlp", jax.random.PRNGKey(0), 8, 3)[0])
    art = load_servable(path, spec)
    server = ClusterPlaneServer.from_artifact(art, spec, apply_fn=apply)
    # serve every trained client's own mixture in one batch
    out = server.predict(art.u_table, jnp.asarray(data.x[:, 0]))
    assert out.shape == (5, 3) and np.isfinite(np.asarray(out)).all()
    assert server.n_compiles == 1 and server.n_dispatches == 1


def test_export_requires_keep_state():
    exp = PaperExpConfig(n_clients=4, n_per_client=16, rounds=1, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=4, n_clusters=2, n_per_client=16, dim=8, n_classes=3,
        seed=1, noise=0.3,
    )
    res = run_method("fedspd", data, exp,
                     cfg=RunConfig(param_plane=True, eval_every=100))
    with pytest.raises(ValueError, match="keep_state"):
        export_run(res, "/tmp/should_not_exist.npz")


# ------------------------------------------------------------------
# deprecation shims + AST call-site guard
# ------------------------------------------------------------------


def test_legacy_generate_shim_warns_and_matches_server():
    from repro.launch.serve import generate

    cfg = get_smoke_config("olmo-1b")
    bundle = build_model(cfg, attn_mode="ref")
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    prompts = jax.random.randint(key, (2, 8), 0, cfg.vocab, jnp.int32)
    with pytest.warns(DeprecationWarning, match="ClusterPlaneServer"):
        toks = generate(bundle, params, prompts, gen_len=4, max_len=13)
    spec = make_pack_spec(params)
    server = ClusterPlaneServer(spec, plane=pack(params, spec)[None, :],
                                bundle=bundle)
    ref = server.generate(jnp.ones((2, 1)), prompts, gen=4)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))


def test_no_repo_caller_uses_deprecated_serving_surface():
    """No module in src/, benchmarks/ or examples/ may still call the
    deprecated serving surface: ``launch.serve.generate`` (module-level
    decode loop), ``ckpt.save(metadata=...)``, or a ``--ckpt`` flag passed
    to ``serve.main``/``serve_mod.main`` — all shims for EXTERNAL callers
    only (tests may exercise them; launch/serve.py defines the shims)."""
    offenders = []
    shim_def = REPO / "src" / "repro" / "launch" / "serve.py"
    for top in ("src", "benchmarks", "examples"):
        for path in sorted((REPO / top).rglob("*.py")):
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in ast.walk(tree):
                if isinstance(node, ast.ImportFrom) and \
                        node.module == "repro.launch.serve":
                    if any(a.name == "generate" for a in node.names):
                        offenders.append(
                            f"{path.relative_to(REPO)}:{node.lineno} "
                            "imports deprecated launch.serve.generate")
                if not isinstance(node, ast.Call):
                    continue
                name = getattr(node.func, "id",
                               getattr(node.func, "attr", None))
                if name == "save" and any(
                        kw.arg == "metadata" for kw in node.keywords):
                    offenders.append(
                        f"{path.relative_to(REPO)}:{node.lineno} "
                        "uses ckpt.save(metadata=...)")
                if name == "main" and path != shim_def:
                    for arg in node.args:
                        for c in ast.walk(arg):
                            if isinstance(c, ast.Constant) and \
                                    c.value == "--ckpt":
                                offenders.append(
                                    f"{path.relative_to(REPO)}:"
                                    f"{node.lineno} serves via --ckpt")
    assert not offenders, (
        "deprecated serving surface in repo callers (use serve/ "
        "ServeConfig + artifacts):\n" + "\n".join(offenders)
    )


# ------------------------------------------------------------------
# CkptManifest: typed sidecar, hard errors, legacy blob
# ------------------------------------------------------------------


def test_manifest_need_names_missing_fields():
    with pytest.raises(KeyError, match=r"\['n_clients', 'pack_digest'\]"):
        ckpt.CkptManifest().need("n_clients", "pack_digest")


def test_manifest_check_names_mismatched_fields():
    m = ckpt.CkptManifest(arch="mlp", plane_shape=(2, 10))
    with pytest.raises(ValueError, match="plane_shape"):
        m.check(arch="mlp", plane_shape=(2, 11))
    assert m.check(arch="mlp", plane_shape=(2, 10)) is m


def test_manifest_roundtrip_and_peek(tmp_path):
    path = str(tmp_path / "m.npz")
    m = ckpt.CkptManifest(kind="servable", arch="mlp", n_clients=4,
                          n_clusters=2, plane_shape=(2, 99),
                          pack_digest="ab", codec="int4", qblock=16,
                          extra={"note": "hi"})
    ckpt.save(path, {"a": np.ones(2)}, manifest=m)
    assert ckpt.read_manifest(path) == m
    _, back = ckpt.restore(path, {"a": np.ones(2)})
    assert back == m


def test_legacy_metadata_kwarg_and_blob_reader(tmp_path):
    path = str(tmp_path / "legacy.npz")
    tree = {"a": np.arange(3.0)}
    with pytest.warns(DeprecationWarning, match="manifest=CkptManifest"):
        ckpt.save(path, tree, metadata={"round": 7, "n_clients": 9})
    _, m = ckpt.restore(path, tree)
    assert m.n_clients == 9 and m.extra["round"] == 7
    # a v1 __metadata__ blob still loads, with a deprecation warning
    import json

    raw = json.dumps({"arch": "mlp", "foo": 1}).encode()
    np.savez(str(tmp_path / "v1.npz"),
             __metadata__=np.frombuffer(raw, dtype=np.uint8),
             **{"['a']": np.arange(3.0)})
    with pytest.warns(DeprecationWarning, match="legacy __metadata__"):
        _, m1 = ckpt.restore(str(tmp_path / "v1.npz"), tree)
    assert m1.version == 1 and m1.arch == "mlp" and m1.extra["foo"] == 1


def test_save_rejects_manifest_plus_metadata(tmp_path):
    with pytest.raises(ValueError, match="not both"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ckpt.save(str(tmp_path / "x.npz"), {"a": np.ones(1)},
                      manifest=ckpt.CkptManifest(), metadata={"x": 1})
