"""Unit + integration tests for the paper's core algorithm (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import (
    cluster_all_clients,
    clustering_accuracy,
    mixture_coefficients,
)
from repro.core.fedspd import (
    FedSPDConfig,
    seeded_init,
    final_phase,
    init_state,
    make_round_step,
    personalize,
    select_clusters,
)
from repro.core.gossip import (
    GossipSpec,
    consensus_distance,
    fedspd_weight_matrix,
    mix,
    mix_dense,
    mix_permute,
    round_comm_bytes,
)
from repro.data.synthetic import make_mixture_classification
from repro.graphs.topology import make_graph, ring
from repro.models.smallnets import make_classifier


def _simple_setup(n=8, s=2, m=64, dim=8, seed=0):
    data = make_mixture_classification(
        n_clients=n, n_clusters=s, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    key = jax.random.PRNGKey(seed)
    params, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        "mlp", key, data.x.shape[-1], data.n_classes
    )
    return data, loss_fn, pel_fn, acc_fn


def test_select_clusters_distribution():
    key = jax.random.PRNGKey(0)
    u = jnp.array([[0.9, 0.1]] * 2000 + [[0.1, 0.9]] * 2000)
    s = select_clusters(key, u)
    assert s.shape == (4000,)
    # clients with 90% mass on cluster 0 mostly select 0
    frac0 = float(jnp.mean((s[:2000] == 0)))
    frac1 = float(jnp.mean((s[2000:] == 1)))
    assert frac0 > 0.85 and frac1 > 0.85


def test_select_never_picks_zero_mass_cluster():
    key = jax.random.PRNGKey(1)
    u = jnp.array([[1.0, 0.0]] * 512)
    s = select_clusters(key, u)
    assert int(jnp.sum(s)) == 0


def test_weight_matrix_row_stochastic_and_matched():
    g = make_graph("er", 12, 4.0, seed=3)
    spec = GossipSpec.from_graph(g)
    s = jnp.array([0, 1] * 6)
    w = np.asarray(fedspd_weight_matrix(spec, s))
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
    # Eq. (1): positive weight only for closed-neighborhood same-selection
    for i in range(12):
        for j in range(12):
            if w[i, j] > 0 and i != j:
                assert g.adj[i, j] == 1.0, "non-neighbor mixed in"
                assert int(s[i]) == int(s[j]), "cluster mismatch mixed in"
    assert np.all(np.diag(w) > 0)  # closed neighborhood includes self


def test_mix_permute_equals_dense():
    """The edge-colored collective_permute schedule computes Eq. (1) exactly."""
    for seed in range(3):
        g = make_graph("er", 10, 4.0, seed=seed)
        spec_d = GossipSpec.from_graph(g, mode="dense")
        spec_p = GossipSpec.from_graph(g, mode="permute")
        key = jax.random.PRNGKey(seed)
        tree = {
            "a": jax.random.normal(key, (10, 5, 3)),
            "b": jax.random.normal(key, (10, 17)),
        }
        s = jax.random.randint(key, (10,), 0, 2)
        out_d = mix_dense(spec_d, tree, s)
        out_p = mix_permute(spec_p, tree, s)
        for k in tree:
            np.testing.assert_allclose(out_d[k], out_p[k], atol=1e-5)


def test_mix_ring_consensus_contracts():
    """Repeated mixing on a connected graph contracts consensus distance."""
    g = ring(8)
    spec = GossipSpec.from_graph(g)
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (8, 20))}
    s = jnp.zeros((8,), jnp.int32)  # everyone same cluster
    d0 = float(consensus_distance(tree))
    for _ in range(30):
        tree = mix(spec, tree, s)
    d1 = float(consensus_distance(tree))
    assert d1 < 1e-3 * d0


def test_comm_bytes_point_to_point_less_than_multicast():
    g = make_graph("er", 16, 6.0, seed=0)
    spec = GossipSpec.from_graph(g)
    key = jax.random.PRNGKey(0)
    s = jax.random.randint(key, (16,), 0, 2)
    p2p = float(round_comm_bytes(spec, s, 1000, point_to_point=True))
    multi = float(round_comm_bytes(spec, s, 1000, point_to_point=False))
    assert p2p <= multi
    assert p2p > 0


@pytest.mark.slow
def test_clustering_recovers_ground_truth():
    """With well-separated centers, min-loss labeling recovers provenance."""
    data, loss_fn, pel_fn, acc_fn = _simple_setup(n=6, m=96, seed=1)
    key = jax.random.PRNGKey(0)

    # train an oracle model per cluster on pooled same-cluster data
    from repro.optim.sgd import sgd
    opt = sgd()
    params, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        "mlp", key, data.x.shape[-1], data.n_classes
    )
    oracle = []
    for c in range(data.n_clusters):
        mask = data.z_true.reshape(-1) == c
        x = jnp.asarray(data.x.reshape(-1, data.x.shape[-1])[mask])
        y = jnp.asarray(data.y.reshape(-1)[mask])
        p = params
        st = opt.init(p)
        g = jax.jit(jax.grad(loss_fn))
        for i in range(150):
            p, st = opt.update(g(p, {"x": x, "y": y}), st, p, 0.1)
        oracle.append(p)

    centers = jax.tree.map(lambda *ls: jnp.stack(
        [jnp.stack([l] * data.n_clients) for l in ls]), *oracle)
    batch = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    z, u = cluster_all_clients(pel_fn, centers, {
        "x": batch["inputs"], "y": batch["targets"]}, data.n_clusters)
    acc = clustering_accuracy(jnp.asarray(z), jnp.asarray(data.z_true), 2)
    assert float(acc) > 0.9, f"clustering acc {float(acc)}"
    # u sums to one
    np.testing.assert_allclose(np.asarray(u).sum(-1), 1.0, atol=1e-5)


def test_mixture_coefficients():
    z = jnp.array([0, 0, 1, 1, 1, 0, 1, 1])
    u = mixture_coefficients(z, 2)
    np.testing.assert_allclose(np.asarray(u), [3 / 8, 5 / 8], atol=1e-6)


@pytest.mark.parametrize("regime", ["full", "stream"])
def test_round_step_runs_and_preserves_invariants(regime):
    data, loss_fn, pel_fn, acc_fn = _simple_setup(n=6, m=48)
    n, s = 6, 2
    fcfg = FedSPDConfig(n_clients=n, n_clusters=s, tau=2, batch=8,
                        regime=regime)
    g = make_graph("er", n, 3.0, seed=0)
    spec = GossipSpec.from_graph(g)
    key = jax.random.PRNGKey(0)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, data.x.shape[-1], data.n_classes)
        return p

    state = init_state(key, model_init, fcfg, data.points_per_client)
    step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))
    if regime == "full":
        payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    else:
        payload = {"x": jnp.asarray(data.x[:, :8]), "y": jnp.asarray(data.y[:, :8])}
    for _ in range(3):
        state, metrics = step(state, payload)
    u = np.asarray(state.u)
    np.testing.assert_allclose(u.sum(-1), 1.0, atol=1e-4)
    assert np.all(u >= 0)
    assert int(state.round) == 3
    assert float(state.comm_bytes) > 0
    assert not any(np.isnan(np.asarray(l)).any()
                   for l in jax.tree.leaves(state.centers))


def test_personalize_is_convex_combination():
    fcfg = FedSPDConfig(n_clients=3, n_clusters=2)
    key = jax.random.PRNGKey(0)

    def model_init(k):
        return {"w": jax.random.normal(k, (4,))}

    state = init_state(key, model_init, fcfg, data_m=1)
    # set u deterministically
    u = jnp.array([[1.0, 0.0], [0.0, 1.0], [0.5, 0.5]])
    state = state._replace(u=u)
    pers = personalize(state)
    c = state.centers["w"]  # (S, N, 4)
    np.testing.assert_allclose(pers["w"][0], c[0, 0], atol=1e-6)
    np.testing.assert_allclose(pers["w"][1], c[1, 1], atol=1e-6)
    np.testing.assert_allclose(
        pers["w"][2], 0.5 * c[0, 2] + 0.5 * c[1, 2], atol=1e-6)


@pytest.mark.slow
def test_fedspd_learns_mixture_end_to_end():
    """Integration: FedSPD (client-seeded warm start, paper Assumption 5.6)
    on separable mixture data reaches high personalized accuracy and
    recovers the mixture coefficients (paper Tables 2-3 behaviour)."""
    data, loss_fn, pel_fn, acc_fn = _simple_setup(n=8, m=96, seed=5)
    data2 = make_mixture_classification(
        n_clients=8, n_clusters=2, n_per_client=96, dim=8, n_classes=4,
        seed=5, noise=0.2,
    )
    n, s = 8, 2
    fcfg = FedSPDConfig(n_clients=n, n_clusters=s, tau=5, batch=16, lr0=0.05,
                        tau_final=10)
    g = make_graph("er", n, 4.0, seed=1)
    spec = GossipSpec.from_graph(g)
    key = jax.random.PRNGKey(2)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, data2.x.shape[-1], data2.n_classes)
        return p

    train = {"inputs": jnp.asarray(data2.x), "targets": jnp.asarray(data2.y)}
    state = seeded_init(key, model_init, fcfg, loss_fn, train)
    step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))
    for _ in range(40):
        state, metrics = step(state, train)

    personalized = final_phase(state, loss_fn, train, fcfg)
    test = {"x": jnp.asarray(data2.x_test), "y": jnp.asarray(data2.y_test)}
    accs = jax.vmap(acc_fn)(personalized, test)
    mean_acc = float(jnp.mean(accs))
    # single-seed trajectory: the margin absorbs XLA-version float drift
    # (the CI matrix runs jax latest), not just sampling noise
    assert mean_acc > 0.7, f"FedSPD acc {mean_acc}"

    # u correlates with ground-truth mixture (up to cluster permutation)
    u = np.asarray(state.u)
    mt = data2.mix_true
    direct = np.abs(u - mt).mean()
    flipped = np.abs(u - mt[:, ::-1]).mean()
    assert min(direct, flipped) < 0.2
