"""Telemetry subsystem (src/repro/telemetry/).

PR-9 acceptance criteria: (a) every traced round-metric stream is
bit-identical between the Python-loop and lax.scan engines — including
under the fully composed scenario (heterogeneity + dropout + cohort
subsampling + int8 codec); (b) collection is compile/dispatch-neutral:
a scan-rolled run with telemetry ON still reports exactly one compile
and one dispatch, and the training result is bitwise unchanged vs
telemetry off; (c) the JSONL event log round-trips every float exactly;
(d) the serve path exposes latency/QPS/dequant/plane-residency counters.
"""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.codecs import Channel, CommConfig, int4_pack
from repro.configs.paper_cnn import PaperExpConfig
from repro.core.packing import make_pack_spec, pack
from repro.data.synthetic import make_mixture_classification
from repro.experiments import (
    ClientSystemModel,
    RunConfig,
    Scenario,
    TelemetryConfig,
    run_method,
    run_method_batch,
)
from repro.models.smallnets import make_classifier
from repro.telemetry import (
    STREAMS,
    LatencyStats,
    compile_count,
    effective_degree,
    inactive_count,
    mixture_drift,
    mixture_entropy,
    read_events,
    run_events,
    spectral_gap_proxy,
    staleness_histogram,
    streams_from_events,
    summary_table,
    write_run_jsonl,
)
from repro.telemetry.metrics import consensus_residual, flatten_centers


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(n_clients=6, n_per_client=32, rounds=4, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=7, noise=0.3,
    )
    return exp, data


def _assert_streams_equal(a, b):
    assert sorted(a.telemetry["streams"]) == sorted(b.telemetry["streams"])
    for name, v in a.telemetry["streams"].items():
        np.testing.assert_array_equal(
            v, b.telemetry["streams"][name], err_msg=name)


# ------------------------------------------------------------------
# metric units
# ------------------------------------------------------------------


def test_mixture_entropy_bounds():
    n, s = 5, 4
    uniform = jnp.full((n, s), 1.0 / s)
    hard = jax.nn.one_hot(jnp.arange(n) % s, s)
    np.testing.assert_allclose(mixture_entropy(uniform), np.log(s),
                               rtol=1e-6)
    np.testing.assert_allclose(mixture_entropy(hard), 0.0, atol=1e-7)


def test_mixture_drift_zero_and_positive():
    u = jnp.asarray(np.random.default_rng(0).dirichlet(
        np.ones(3), size=6), jnp.float32)
    assert float(mixture_drift(u, u)) == 0.0
    assert float(mixture_drift(u, u * 0.5)) > 0.0


def test_consensus_residual_zero_at_consensus():
    plane = jnp.broadcast_to(jnp.arange(7.0), (2, 4, 7))  # all clients equal
    np.testing.assert_allclose(consensus_residual(plane), np.zeros(2),
                               atol=1e-7)


def test_effective_degree_complete_and_empty():
    n = 6
    full = jnp.ones((n, n))
    assert float(effective_degree(full)) == n - 1
    assert float(effective_degree(jnp.zeros((n, n)))) == 0.0


def test_spectral_gap_complete_graph_beats_ring():
    n = 8
    complete = np.ones((n, n)) - np.eye(n)
    ring = np.zeros((n, n))
    for i in range(n):
        ring[i, (i + 1) % n] = ring[i, (i - 1) % n] = 1.0
    g_complete = float(spectral_gap_proxy(jnp.asarray(complete)))
    g_ring = float(spectral_gap_proxy(jnp.asarray(ring)))
    assert 0.0 < g_ring < g_complete <= 1.0
    # empty graph: everyone isolated, no mixing, gap 0
    assert float(spectral_gap_proxy(jnp.zeros((n, n)))) == 0.0


def test_staleness_histogram_counts_and_overflow():
    stale = jnp.asarray([0, 0, 1, 2, 7, 9], jnp.int32)
    h = staleness_histogram(stale, bins=4)
    np.testing.assert_array_equal(h, [2, 1, 1, 2])  # >=3 overflows
    assert float(h.sum()) == 6


def test_inactive_count():
    w = jnp.asarray([0.0, 0.5, 1.0, 0.0])
    assert float(inactive_count(w)) == 2.0


def test_flatten_centers_pytree_and_plane():
    centers = {"a": jnp.ones((2, 3, 4)), "b": jnp.zeros((2, 3, 5, 2))}
    plane = flatten_centers(centers)
    assert plane.shape == (2, 3, 14)
    packed = jnp.ones((2, 3, 9))
    assert flatten_centers(packed) is packed


def test_compile_count_on_jitted_fn():
    f = jax.jit(lambda x: x * 2)
    assert compile_count(f) == 0
    f(jnp.ones(3))
    assert compile_count(f) == 1
    f(jnp.ones(3))
    assert compile_count(f) == 1
    assert compile_count(object()) == -1


def test_latency_stats_percentiles_and_qps():
    st = LatencyStats()
    for ms in (1, 2, 3, 4, 100):
        st.record(ms / 1e3, batch=2)
    snap = st.snapshot()
    assert snap["batches"] == 5 and snap["requests"] == 10
    assert snap["p50_ms"] == pytest.approx(3.0)
    assert snap["p99_ms"] == pytest.approx(100.0)
    assert snap["qps"] > 0


# ------------------------------------------------------------------
# engine parity + compile/dispatch neutrality
# ------------------------------------------------------------------


def test_streams_bit_identical_loop_vs_scan(setup):
    exp, data = setup
    cfg = RunConfig(eval_every=2, telemetry=TelemetryConfig())
    loop = run_method("fedspd", data, exp, seed=0, cfg=cfg)
    scan = run_method("fedspd", data, exp, seed=0,
                      cfg=dataclasses.replace(cfg, scan_rounds=True))
    assert sorted(loop.telemetry["streams"]) == sorted(STREAMS)
    assert loop.telemetry["rounds"] == exp.rounds
    _assert_streams_equal(loop, scan)
    # ACCEPTANCE: telemetry ON keeps the scan engine at one compile and
    # one dispatch, and the loop engine at one compile
    assert scan.extras["n_compiles"] == 1
    assert scan.extras["n_dispatches"] == 1
    assert loop.extras["n_compiles"] == 1
    assert loop.extras["n_dispatches"] == exp.rounds


def test_streams_parity_fully_composed(setup):
    """het + dropout + cohort + int8 codec + error feedback, both
    engines: every stream (including the staleness histogram and the
    inactive count) is bit-identical."""
    exp, data = setup
    het = ClientSystemModel(
        slow_fraction=0.34, slow_factor=4.0, time_budget=1.5, jitter=0.3,
        p_unavailable=0.2, staleness_gamma=0.7, seed=11,
    )
    cfg = RunConfig(
        param_plane=True, eval_every=2, cohort_size=4,
        scenario=Scenario(dropout=0.2, seed=11, system=het),
        comm=CommConfig(codec="int8", error_feedback=True),
        telemetry=TelemetryConfig(),
    )
    loop = run_method("fedspd", data, exp, seed=0, cfg=cfg)
    scan = run_method("fedspd", data, exp, seed=0,
                      cfg=dataclasses.replace(cfg, scan_rounds=True))
    _assert_streams_equal(loop, scan)
    assert scan.extras["n_compiles"] == 1
    assert scan.extras["n_dispatches"] == 1
    # the heterogeneity streams actually fired
    assert float(np.sum(loop.telemetry["streams"]["n_inactive"])) > 0
    hist = loop.telemetry["streams"]["stale_hist"]
    np.testing.assert_allclose(hist.sum(axis=-1),
                               np.full(exp.rounds, exp.n_clients))
    # wire bytes reflect the int8 codec: below logical on every round
    # that moved bytes at all (an all-inactive round moves zero of both)
    s = loop.telemetry["streams"]
    moved = s["logical_bytes"] > 0
    assert moved.any()
    assert np.all(s["wire_bytes"][moved] < s["logical_bytes"][moved])
    np.testing.assert_array_equal(loop.extras["staleness"],
                                  scan.extras["staleness"])


def test_telemetry_on_does_not_change_training(setup):
    exp, data = setup
    for scan_rounds in (False, True):
        cfg = RunConfig(eval_every=2, scan_rounds=scan_rounds)
        off = run_method("fedspd", data, exp, seed=0, cfg=cfg)
        on = run_method(
            "fedspd", data, exp, seed=0,
            cfg=dataclasses.replace(cfg, telemetry=TelemetryConfig()))
        np.testing.assert_array_equal(off.acc_per_client, on.acc_per_client)
        np.testing.assert_array_equal(np.asarray(off.extras["u"]),
                                      np.asarray(on.extras["u"]))
        assert off.extras["n_compiles"] == on.extras["n_compiles"]
        assert off.extras["n_dispatches"] == on.extras["n_dispatches"]
        # telemetry without a system model still reports staleness — the
        # all-zeros counters, identically from both engines
        np.testing.assert_array_equal(
            on.extras["staleness"], np.zeros(exp.n_clients, np.int32))
        assert off.telemetry is None and on.telemetry is not None


def test_batched_runs_slice_streams_per_seed(setup):
    exp, data = setup
    cfg = RunConfig(eval_every=2, telemetry=TelemetryConfig())
    loop = run_method_batch("fedspd", data, exp, seeds=(0, 1), cfg=cfg)
    scan = run_method_batch("fedspd", data, exp, seeds=(0, 1),
                            cfg=dataclasses.replace(cfg, scan_rounds=True))
    assert scan[0].extras["n_compiles"] == 1
    for a, b in zip(loop, scan):
        _assert_streams_equal(a, b)
    for r in loop:
        assert r.telemetry["streams"]["u_entropy"].shape == (exp.rounds,)
        assert r.telemetry["streams"]["consensus"].shape == (exp.rounds, 2)
    # seeds actually differ (drift depends on the per-seed key stream)
    assert not np.array_equal(loop[0].telemetry["streams"]["u_drift"],
                              loop[1].telemetry["streams"]["u_drift"])


def test_telemetry_disabled_config_is_off(setup):
    exp, data = setup
    r = run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(eval_every=2,
                                 telemetry=TelemetryConfig(
                                     round_metrics=False)))
    assert r.telemetry is None


def test_telemetry_config_validates():
    with pytest.raises(ValueError):
        TelemetryConfig(power_iters=0)
    with pytest.raises(ValueError):
        TelemetryConfig(staleness_bins=1)


def test_pytree_engine_reports_nan_consensus(setup):
    """The per-leaf pytree engine has no packed plane; the consensus
    stream degrades to NaN instead of failing the run."""
    exp, data = setup
    r = run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(eval_every=2, param_plane=False,
                                 telemetry=TelemetryConfig()))
    # fedspd pytree centers still expose the (S, N, ...) leaf structure,
    # so consensus may be real; the local baseline has no u at all
    r2 = run_method("local", data, exp, seed=0,
                    cfg=RunConfig(eval_every=2,
                                  telemetry=TelemetryConfig()))
    assert np.all(np.isnan(r2.telemetry["streams"]["u_entropy"]))
    assert r.telemetry is not None


# ------------------------------------------------------------------
# JSONL event log: write -> parse -> identical floats
# ------------------------------------------------------------------


def test_jsonl_round_trip_exact(setup, tmp_path):
    exp, data = setup
    r = run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(eval_every=2, scan_rounds=True,
                                 telemetry=TelemetryConfig()))
    path = tmp_path / "telemetry.jsonl"
    write_run_jsonl(str(path), r, meta={"seed": 0})
    events = read_events(str(path))
    kinds = [e["event"] for e in events]
    assert kinds[0] == "run_meta" and kinds[-1] == "summary"
    assert kinds.count("round") == exp.rounds
    parsed = streams_from_events(events)
    for name, orig in r.telemetry["streams"].items():
        # float32 -> JSON -> float64 widens exactly: bit-identical values
        np.testing.assert_array_equal(
            parsed[name], np.asarray(orig, np.float64), err_msg=name)
    summary = events[-1]
    assert summary["n_compiles"] == 1 and summary["n_dispatches"] == 1
    assert summary["mean_acc"] == r.mean_acc
    # every line is valid standalone JSON
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_run_events_without_telemetry_uses_curve(setup):
    exp, data = setup
    r = run_method("fedspd", data, exp, seed=0, cfg=RunConfig(eval_every=2))
    events = run_events(r)
    rounds = [e for e in events if e["event"] == "round"]
    assert [e["round"] for e in rounds] == [c[0] for c in r.curve]
    assert all("train_acc" in e for e in rounds)


def test_summary_table_renders(setup, tmp_path):
    exp, data = setup
    r = run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(eval_every=2,
                                 telemetry=TelemetryConfig()))
    path = tmp_path / "t.jsonl"
    write_run_jsonl(str(path), r, meta={"seed": 0, "n_clients": 6})
    table = summary_table(read_events(str(path)))
    assert "| stream |" in table
    for name in STREAMS:
        assert f"| {name} |" in table
    assert "n_compiles=1" in table


# ------------------------------------------------------------------
# serve-path telemetry
# ------------------------------------------------------------------


def _mlp_server(codec="fp32", s=3, dim=16, qb=16):
    from repro.serve import ClusterPlaneServer

    key = jax.random.PRNGKey(0)
    _, apply, *_ = make_classifier("mlp", key, dim, 4)

    def model_init(k):
        return make_classifier("mlp", k, dim, 4)[0]

    spec = make_pack_spec(jax.eval_shape(model_init, key))
    plane = jnp.stack([pack(model_init(jax.random.PRNGKey(i)), spec)
                       for i in range(s)])
    if codec == "fp32":
        return ClusterPlaneServer(spec, plane=plane, apply_fn=apply), spec
    ch = Channel(CommConfig(codec=codec, block=qb), spec.size)
    enc = ch.encode(plane, key, rounding="nearest")
    kw = ({"plane_q": enc["q"]} if codec == "int8"
          else {"plane_packed": int4_pack(enc["q"])})
    return ClusterPlaneServer(spec, codec=codec, qblock=qb,
                              plane_scale=enc["scale"], apply_fn=apply,
                              **kw), spec


def test_serve_latency_and_residency_counters():
    server, spec = _mlp_server()
    rng = np.random.default_rng(0)
    u = rng.dirichlet(np.ones(3), size=5).astype(np.float32)
    x = rng.normal(size=(5, 16)).astype(np.float32)
    server.predict(u, x)
    server.predict(u, x)
    snap = server.telemetry_snapshot()
    assert snap["n_dispatches"] == 2 and snap["n_compiles"] == 1
    assert snap["dequant_calls"] == 0          # fp32: einsum path
    assert snap["batches"] == 2 and snap["requests"] == 10
    assert snap["p50_ms"] > 0 and snap["qps"] > 0
    assert snap["p95_ms"] >= snap["p50_ms"]
    assert snap["plane_bytes"] == 3 * spec.size * 4
    json.dumps(snap)                           # JSON-able as-is


def test_serve_dequant_counter_and_smaller_residency():
    server, spec = _mlp_server(codec="int8")
    rng = np.random.default_rng(1)
    u = rng.dirichlet(np.ones(3), size=4).astype(np.float32)
    x = rng.normal(size=(4, 16)).astype(np.float32)
    server.predict(u, x)
    snap = server.telemetry_snapshot()
    assert snap["dequant_calls"] == 1
    assert snap["plane_bytes"] < 3 * spec.size * 4   # int8 < fp32 resident


# ------------------------------------------------------------------
# deprecation shims blame the caller (stacklevel)
# ------------------------------------------------------------------


def test_legacy_kwargs_warning_names_this_file(setup):
    exp, data = setup
    small = dataclasses.replace(exp, rounds=1)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        run_method("fedspd", data, small, seed=0, eval_every=5)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert dep and dep[0].filename == __file__


def test_legacy_generate_shim_warning_names_this_file():
    from repro.configs.base import get_smoke_config
    from repro.launch.serve import generate
    from repro.models.registry import build_model

    cfg = get_smoke_config("olmo-1b")
    bundle = build_model(cfg, attn_mode="ref")
    params = bundle.init(jax.random.PRNGKey(0))
    prompts = jnp.zeros((1, 4), jnp.int32)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        generate(bundle, params, prompts, gen_len=2, max_len=8)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert dep and dep[0].filename == __file__
