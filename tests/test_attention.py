"""blocked_attention (the dry-run/production pure-JAX flash path) vs the
materialized reference, plus the sequence-sharded decode combine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    blocked_attention,
    decode_attention,
    decode_attention_parts,
    ref_attention,
)

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize(
    "lq,lkv,hq,hkv,window,qb,kb",
    [
        (256, 256, 4, 2, None, 64, 64),
        (256, 256, 4, 1, 100, 64, 32),
        (128, 128, 2, 2, 64, 128, 128),   # single block
        (512, 512, 8, 2, None, 256, 128),  # uneven block shapes
    ],
)
def test_blocked_matches_ref(lq, lkv, hq, hkv, window, qb, kb):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, lq, hq, 32))
    k = jax.random.normal(ks[1], (2, lkv, hkv, 32))
    v = jax.random.normal(ks[2], (2, lkv, hkv, 32))
    out = blocked_attention(q, k, v, causal=True, window=window,
                            q_block=qb, kv_block=kb)
    want = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_blocked_dyn_window_matches_static():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    stat = blocked_attention(q, k, v, causal=True, window=48,
                             q_block=32, kv_block=32)
    dyn = blocked_attention(q, k, v, causal=True, window=None,
                            dyn_window=jnp.int32(48), q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(stat), np.asarray(dyn), atol=2e-5)


def test_decode_attention_matches_last_row_of_full():
    """Decoding token t against the cache == row t of full causal attention."""
    ks = jax.random.split(KEY, 3)
    l, hq, hkv, hd = 64, 4, 2, 16
    q_all = jax.random.normal(ks[0], (2, l, hq, hd))
    k_all = jax.random.normal(ks[1], (2, l, hkv, hd))
    v_all = jax.random.normal(ks[2], (2, l, hkv, hd))
    full = ref_attention(q_all, k_all, v_all, causal=True)
    t = l - 1
    out = decode_attention(
        q_all[:, t : t + 1], k_all, v_all, jnp.asarray(t)
    )
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, t]),
                               atol=2e-5)


def test_decode_sharded_combine_exact():
    """Flash-decoding combine over cache shards == unsharded decode."""
    ks = jax.random.split(KEY, 3)
    l, hq, hkv, hd, shards = 64, 4, 2, 16, 4
    q = jax.random.normal(ks[0], (2, 1, hq, hd))
    k = jax.random.normal(ks[1], (2, l, hkv, hd))
    v = jax.random.normal(ks[2], (2, l, hkv, hd))
    cur = jnp.asarray(l - 1)
    want = decode_attention(q, k, v, cur)

    # manual shard-and-combine (what the mesh does via psum)
    ls = l // shards
    ms, lls, os_ = [], [], []
    for i in range(shards):
        pos = i * ls + jnp.arange(ls)
        m, lv, o = decode_attention_parts(
            q, k[:, i * ls : (i + 1) * ls], v[:, i * ls : (i + 1) * ls],
            pos, cur)
        ms.append(m); lls.append(lv); os_.append(o)
    m = jnp.stack(ms); lv = jnp.stack(lls); o = jnp.stack(os_)
    M = jnp.max(m, axis=0)
    alpha = jnp.exp(m - M)
    l_tot = jnp.sum(lv * alpha, axis=0)
    o_tot = jnp.sum(o * alpha[..., None], axis=0)
    got = (o_tot / jnp.maximum(l_tot[..., None], 1e-30)).reshape(2, 1, hq, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_window_masks_old_positions():
    ks = jax.random.split(KEY, 3)
    l = 32
    q = jax.random.normal(ks[0], (1, 1, 2, 8))
    k = jax.random.normal(ks[1], (1, l, 2, 8))
    v = jax.random.normal(ks[2], (1, l, 2, 8))
    cur = jnp.asarray(l - 1)
    win = 8
    out = decode_attention(q, k, v, cur, window=win)
    # equivalent: zero out everything outside the window manually
    k2 = k.at[:, : l - win].set(1e6)  # poison old keys; must not matter
    v2 = v.at[:, : l - win].set(1e6)
    out2 = decode_attention(q, k2, v2, cur, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-4)
