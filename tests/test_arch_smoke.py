"""Per-assigned-architecture smoke tests (brief deliverable (f)): reduced
same-family variant (≤2 layers, d_model ≤ 512, ≤4 experts), one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_ALIASES, INPUT_SHAPES, get_config, get_smoke_config
from repro.models.registry import active_params, build_model, count_params

pytestmark = pytest.mark.slow

ARCHS = sorted(set(ARCH_ALIASES) - {"phi3_5-moe-42b-a6_6b", "h2o-danube-1_8b",
                                    "zamba2-1_2b"})  # drop alias duplicates


def _batch(cfg, key, b=2, l=32):
    batch = {"tokens": jax.random.randint(key, (b, l), 0, cfg.vocab)}
    if cfg.family == "audio":
        d_enc = cfg.encoder_d_model or cfg.d_model
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames or 16, d_enc), jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_variant_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg, attn_mode="ref")
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    batch = _batch(cfg, key)

    logits, aux = bundle.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())

    # one SGD train step moves the loss
    loss0, grads = jax.value_and_grad(bundle.loss)(params, batch)
    assert np.isfinite(float(loss0))
    assert not any(np.isnan(np.asarray(g)).any() for g in jax.tree.leaves(grads))
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss1 = bundle.loss(params2, batch)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_path(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg, attn_mode="ref")
    key = jax.random.PRNGKey(1)
    params = bundle.init(key)
    batch = _batch(cfg, key, b=2, l=16)
    cache = bundle.init_cache(2, 24)
    cache = bundle.prefill(params, batch, cache)
    logits, cache2 = bundle.decode_step(params, cache, batch["tokens"][:, :1])
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert not bool(jnp.isnan(logits).any())
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config must match the published shape (never allocated on
    CPU — exercised via ShapeDtypeStruct dry-runs only)."""
    cfg = get_config(arch)
    expected = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expected, f"{arch}: {got} != {expected}"
    assert cfg.citation


def test_moe_configs():
    assert get_config("olmoe-1b-7b").n_experts == 64
    assert get_config("olmoe-1b-7b").top_k == 8
    assert get_config("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert get_config("phi3.5-moe-42b-a6.6b").top_k == 2


def test_param_counts_plausible():
    """Analytic parameter counts land near the advertised sizes."""
    expect = {
        "olmo-1b": (0.9e9, 1.6e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "granite-3-8b": (7e9, 10e9),
        "chameleon-34b": (30e9, 38e9),
        "gemma3-1b": (0.7e9, 1.4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active < total
    moe = get_config("phi3.5-moe-42b-a6.6b")
    assert active_params(moe) < 0.3 * count_params(moe)


def test_input_shapes_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
