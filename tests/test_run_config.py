"""RunConfig: the one configuration object behind both entry points.

Covers the PR-6 API-redesign satellites: the deprecated loose kwargs warn
and route through the identical driver, mixing the two styles errors,
batch validation errors name the entry point and the offending seed
index, ``extras["n_compiles"]`` is reported identically by both entry
points, and NO caller inside src/ / benchmarks/ / examples/ still uses
the loose kwargs (the call-site guard).
"""
import ast
import pathlib

import numpy as np
import pytest

from repro.comm import CommConfig
from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import RunConfig, run_method, run_method_batch

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(n_clients=6, n_per_client=32, rounds=4, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=7, noise=0.3,
    )
    return exp, data


# ------------------------------------------------------------------
# resolve_options semantics
# ------------------------------------------------------------------


def test_typed_fields_fold_into_options():
    opts = RunConfig(gossip_mode="permute", gossip_backend="pallas",
                     param_plane=True).resolve_options()
    assert opts == {"mode": "permute", "gossip_backend": "pallas",
                    "param_plane": True}
    # explicit options entries win over the typed shorthands
    opts = RunConfig(gossip_backend="pallas",
                     options={"gossip_backend": "reference"}
                     ).resolve_options()
    assert opts["gossip_backend"] == "reference"


def test_compressing_codec_implies_param_plane():
    opts = RunConfig(comm=CommConfig(codec="int8")).resolve_options()
    assert opts["param_plane"] is True
    with pytest.raises(ValueError, match="param_plane=False"):
        RunConfig(comm=CommConfig(codec="int8"),
                  param_plane=False).resolve_options()


def test_run_config_is_frozen():
    with pytest.raises(Exception):
        RunConfig().eval_every = 5


# ------------------------------------------------------------------
# deprecation shims
# ------------------------------------------------------------------


def test_loose_kwargs_warn_and_match_cfg(setup):
    exp, data = setup
    with pytest.warns(DeprecationWarning, match="cfg=RunConfig"):
        old = run_method("fedspd", data, exp, seed=0, eval_every=100,
                         param_plane=True)
    new = run_method("fedspd", data, exp, seed=0,
                     cfg=RunConfig(eval_every=100, param_plane=True))
    np.testing.assert_array_equal(old.acc_per_client, new.acc_per_client)
    np.testing.assert_allclose(old.comm_bytes, new.comm_bytes, rtol=1e-9)


def test_loose_kwargs_warn_on_batch_entry(setup):
    exp, data = setup
    with pytest.warns(DeprecationWarning, match="run_method_batch"):
        rs = run_method_batch("fedspd", data, exp, seeds=(0,),
                              eval_every=100)
    assert np.isfinite(rs[0].mean_acc)


def test_cfg_plus_loose_kwargs_is_an_error(setup):
    exp, data = setup
    with pytest.raises(ValueError, match="not both"):
        run_method("fedspd", data, exp, seed=0, cfg=RunConfig(),
                   eval_every=100)
    with pytest.raises(ValueError, match="run_method_batch"):
        run_method_batch("fedspd", data, exp, seeds=(0,), cfg=RunConfig(),
                         param_plane=True)


# ------------------------------------------------------------------
# batch validation errors name the entry point + seed index
# ------------------------------------------------------------------


def _datasets(k, dims=None):
    return [
        make_mixture_classification(n_clients=6, n_clusters=2,
                                    n_per_client=32,
                                    dim=(dims[i] if dims else 8),
                                    n_classes=3, seed=100 + i, noise=0.3)
        for i in range(k)
    ]


def test_batch_errors_name_entry_point_and_seed_index(setup):
    exp, _ = setup
    with pytest.raises(ValueError,
                       match=r"run_method_batch: stacked data: got 2 "
                             r"datasets for 3 seeds"):
        run_method_batch("fedspd", _datasets(2), exp, seeds=(0, 1, 2))
    # the offending dataset is called out by seed index
    with pytest.raises(ValueError, match=r"seed index 1 \(seed 8\)"):
        run_method_batch("fedspd", _datasets(2, dims=[8, 12]), exp,
                         seeds=(7, 8), cfg=RunConfig(eval_every=100))


# ------------------------------------------------------------------
# both entry points report the same compile accounting
# ------------------------------------------------------------------


@pytest.mark.parametrize("scan", [False, True])
def test_n_compiles_identical_between_entry_points(setup, scan):
    """A single-seed run_method_batch must report the exact n_compiles /
    n_dispatches run_method reports — same driver, same program."""
    exp, data = setup
    cfg = RunConfig(eval_every=100, scan_rounds=scan)
    solo = run_method("fedspd", data, exp, seed=0, cfg=cfg)
    batch = run_method_batch("fedspd", data, exp, seeds=(0,), cfg=cfg)
    assert solo.extras["n_compiles"] == batch[0].extras["n_compiles"] == 1
    assert (solo.extras["n_dispatches"]
            == batch[0].extras["n_dispatches"]
            == (1 if scan else exp.rounds))


# ------------------------------------------------------------------
# call-site guard: the repo itself must not use the deprecated kwargs
# ------------------------------------------------------------------

DEPRECATED = {"eval_every", "gossip_mode", "gossip_backend", "param_plane",
              "comm", "scenario", "options"}


def test_no_repo_caller_uses_deprecated_loose_kwargs():
    """Every run_method / run_method_batch call inside src/, benchmarks/
    and examples/ must pass cfg=RunConfig(...) — the loose kwargs are
    shims for EXTERNAL callers only (tests may exercise them)."""
    offenders = []
    for top in ("src", "benchmarks", "examples"):
        for path in sorted((REPO / top).rglob("*.py")):
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                name = getattr(node.func, "id",
                               getattr(node.func, "attr", None))
                if name not in ("run_method", "run_method_batch"):
                    continue
                bad = DEPRECATED & {kw.arg for kw in node.keywords}
                if bad:
                    offenders.append(
                        f"{path.relative_to(REPO)}:{node.lineno} "
                        f"uses {sorted(bad)}"
                    )
    assert not offenders, (
        "deprecated loose kwargs in repo callers (pass cfg=RunConfig):\n"
        + "\n".join(offenders)
    )
