"""Test-pyramid hygiene guards.

Two meta-tests keep the fast lane honest:

1. a source-level audit that every test touching ``subprocess`` (the
   mesh/ppermute/CLI tests that fork fresh interpreters with forced host
   device counts — the slowest things in the suite) carries
   ``@pytest.mark.slow``, directly or via a module-level ``pytestmark``;
2. an end-to-end collection check that ``-m "not slow"`` (the CI fast
   lane's exact selector) deselects every slow-marked test — guarding
   marker-registration typos and accidental ``slow``/``robustness``
   mix-ups, which silently turn the fast lane into the full lane.
"""
import ast
import pathlib

import pytest

TESTS_DIR = pathlib.Path(__file__).resolve().parent


def _module_marked_slow(tree: ast.Module) -> bool:
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "pytestmark"
            for t in node.targets
        ):
            if "slow" in ast.unparse(node.value):
                return True
    return False


def _uses(node: ast.AST, names: set) -> bool:
    return any(
        isinstance(sub, ast.Name) and sub.id in names
        or isinstance(sub, ast.Attribute) and sub.attr in names
        for sub in ast.walk(node)
    )


def test_every_subprocess_test_is_slow_marked():
    """Any test function that reaches ``subprocess`` — directly or through
    a module helper wrapping it — must carry the slow mark (or live in a
    module whose ``pytestmark`` is slow). Subprocess tests re-import jax
    under a fresh interpreter: they are never fast-lane material."""
    offenders = []
    for path in sorted(TESTS_DIR.glob("test_*.py")):
        src = path.read_text()
        if "subprocess" not in src:
            continue
        tree = ast.parse(src)
        if _module_marked_slow(tree):
            continue
        # names of module-level helpers whose bodies touch subprocess
        helpers = {
            node.name for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and not node.name.startswith("test_")
            and _uses(node, {"subprocess"})
        }
        reach = helpers | {"subprocess"}
        for node in ast.walk(tree):
            if not (isinstance(node, ast.FunctionDef)
                    and node.name.startswith("test_")):
                continue
            if not _uses(node, reach):
                continue
            marked = any("slow" in ast.unparse(d)
                         for d in node.decorator_list)
            if not marked:
                offenders.append(f"{path.name}::{node.name}")
    assert not offenders, (
        "subprocess-reaching tests missing @pytest.mark.slow: "
        f"{offenders}"
    )


class _Collected:
    def __init__(self):
        self.items = None

    def pytest_collection_finish(self, session):
        self.items = list(session.items)


def test_fast_lane_collects_no_slow_tests():
    """Run the CI fast lane's exact collection (``-m "not slow"``)
    in-process and assert (a) it is non-empty and (b) not one surviving
    item carries the slow marker."""
    col = _Collected()
    rc = pytest.main(
        ["--collect-only", "-q", "-m", "not slow",
         "-p", "no:cacheprovider", str(TESTS_DIR)],
        plugins=[col],
    )
    assert rc == 0, f"fast-lane collection failed with exit code {rc}"
    assert col.items, "fast lane collected nothing"
    leaked = [item.nodeid for item in col.items
              if any(m.name == "slow" for m in item.iter_markers())]
    assert not leaked, f"slow-marked tests leaked into the fast lane: {leaked}"
