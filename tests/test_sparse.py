"""Decentralized sparse training (DisPFL) on the packed plane.

Covers the sparse subsystem end to end: SparseConfig statics and the
exact-count RigL update (core/sparse), the mask-aware Pallas kernels
(kernels/gossip_mix), sparse wire-byte accounting (comm/codecs + the
experiment driver), density=1.0 bit-exact dense parity, the full
composition matrix sparse × cohort × ClientSystemModel × int8+EF across
both round engines (bit-identical, one compile / one dispatch under
scan), the bit-untouched inactive-row contract for masks, and the
telemetry density / mask-churn streams.
"""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, make_channel
from repro.comm.codecs import sparse_wire_model_bytes
from repro.configs.paper_cnn import PaperExpConfig
from repro.core.sparse import (
    SparseConfig,
    column_activity,
    init_masks,
    maybe_update_mask,
)
from repro.data.synthetic import make_mixture_classification
from repro.experiments import (
    ClientSystemModel,
    RunConfig,
    Scenario,
    run_method,
)

SP = SparseConfig(density=0.25, prune_rate=0.3, update_every=2)


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(
        n_clients=8, n_per_client=32, rounds=5, tau=1, batch=16,
        avg_degree=4.0, model="mlp", dim=16, n_classes=4,
    )
    data = make_mixture_classification(
        n_clients=8, n_clusters=2, n_per_client=32, dim=16, n_classes=4,
        seed=0,
    )
    return exp, data


def _run(data, exp, **cfg_kw):
    cfg_kw.setdefault("eval_every", 10**9)
    cfg_kw.setdefault("param_plane", True)
    opts = dict(cfg_kw.pop("options", {}))
    opts.setdefault("keep_state", True)
    return run_method("fedspd", data, exp, seed=0,
                      cfg=RunConfig(options=opts, **cfg_kw))


# ---------------------------------------------------------------- statics


def test_sparse_config_validation():
    for bad in (dict(density=0.0), dict(density=1.5),
                dict(prune_rate=1.0), dict(prune_rate=-0.1),
                dict(regrow="magnitude"), dict(update_every=0)):
        with pytest.raises(ValueError):
            SparseConfig(**bad)
    assert not SparseConfig(density=1.0).enabled
    assert SparseConfig(density=0.5).enabled


def test_static_counts():
    cfg = SparseConfig(density=0.2, prune_rate=0.5)
    assert cfg.k_active(100) == 20
    assert cfg.n_prune(100) == 10
    # never more active than X, never fewer than 1, prune capped by the
    # dead-coordinate pool
    assert SparseConfig(density=0.001).k_active(10) == 1
    assert SparseConfig(density=0.9, prune_rate=0.9).n_prune(10) == 1


def test_init_masks_exact_counts():
    cfg = SparseConfig(density=0.3)
    m = np.asarray(init_masks(jax.random.PRNGKey(0), 5, 64, cfg))
    assert m.shape == (5, 64)
    assert set(np.unique(m)) <= {0.0, 1.0}
    np.testing.assert_array_equal(m.sum(-1), cfg.k_active(64))


def test_maybe_update_mask_gates_on_round():
    cfg = SparseConfig(density=0.25, prune_rate=0.4, update_every=3)
    key = jax.random.PRNGKey(1)
    m = init_masks(key, 4, 40, cfg)
    w = jax.random.normal(jax.random.fold_in(key, 1), (4, 40)) * m
    g = jax.random.normal(jax.random.fold_in(key, 2), (4, 40))
    frozen = maybe_update_mask(m, w, g, key, jnp.int32(0), cfg)
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(m))
    frozen = maybe_update_mask(m, w, g, key, jnp.int32(2), cfg)
    np.testing.assert_array_equal(np.asarray(frozen), np.asarray(m))
    fired = maybe_update_mask(m, w, g, key, jnp.int32(3), cfg)
    assert (np.asarray(fired) != np.asarray(m)).any()
    np.testing.assert_array_equal(np.asarray(fired).sum(-1),
                                  cfg.k_active(40))


def test_column_activity():
    m = jnp.asarray([[1.0, 0.0, 0.0], [0.0, 0.0, 1.0]])
    np.testing.assert_array_equal(np.asarray(column_activity(m)),
                                  [1.0, 0.0, 1.0])


# ---------------------------------------------------------------- kernels


def test_gossip_mix_sparse_matches_einsum():
    """The slab-skipping masked W·C == the dense einsum on masked input,
    exactly (interpret mode), including fully dead 128-aligned slabs and
    a padded tail."""
    from repro.kernels.gossip_mix import gossip_mix_sparse

    n, x = 6, 300
    key = jax.random.PRNGKey(0)
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), axis=1)
    mask = np.array(
        init_masks(jax.random.fold_in(key, 1), n, x,
                   SparseConfig(density=0.3)))
    mask[:, 128:256] = 0.0  # one whole slab dead across every client
    mask = jnp.asarray(mask)
    c = jax.random.normal(jax.random.fold_in(key, 2), (n, x)) * mask
    ref = jnp.einsum("ij,jx->ix", w, c,
                     preferred_element_type=jnp.float32)
    for x_block in (None, 128):
        got = gossip_mix_sparse(w, c, column_activity(mask),
                                x_block=x_block, interpret=True)
        np.testing.assert_allclose(np.asarray(got)[:, :x],
                                   np.asarray(ref), atol=1e-5)
        # the dead slab comes out as exact zeros, not roundoff
        assert (np.asarray(got)[:, 128:256] == 0.0).all()


def test_gossip_mix_encoded_masked_matches_reference():
    """Fused masked dequantize+mix == W @ (M ⊙ decode(enc)) exactly in
    interpret mode (same fp32 contraction order)."""
    from repro.kernels.gossip_mix import gossip_mix_encoded_masked

    n, x = 5, 203
    ch = make_channel(CommConfig(codec="int8", block=32), x)
    key = jax.random.PRNGKey(3)
    mask = init_masks(jax.random.fold_in(key, 1), n, x,
                      SparseConfig(density=0.4))
    c = jax.random.normal(key, (n, x)) * mask
    enc = ch.encode(c, jax.random.fold_in(key, 2))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3),
                                         (n, n)), axis=1)
    ref = w @ (mask * ch.decode(enc))
    got = gossip_mix_encoded_masked(w, enc, mask, qblock=32, x_out=x,
                                    out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


# ------------------------------------------------------------- wire bytes


@pytest.mark.parametrize("codec", ["fp32", "int8", "int4", "topk"])
def test_sparse_wire_bound(codec):
    """Acceptance bound: for the density-scaling codecs the sparse
    per-message wire cost is at most density · dense wire cost + the
    support bitmap (sizes chosen so block counts divide exactly — zero
    slack). topk ships explicit (value, index) pairs already, so its
    sparse cost is instead bounded by its own dense cost."""
    x, block, density = 2048, 256, 0.25
    cfg = CommConfig(codec=codec, block=block, error_feedback=False)
    k = SparseConfig(density=density).k_active(x)
    sparse_b = sparse_wire_model_bytes(cfg, x, k)
    bitmap = -(-x // 8)
    if codec == "fp32":
        dense_b = 4 * x
    else:
        dense_b = make_channel(cfg, x).wire_model_bytes
    if codec == "topk":
        assert sparse_b <= dense_b, (sparse_b, dense_b)
    else:
        assert sparse_b <= density * dense_b + bitmap, (sparse_b, dense_b)
    assert sparse_b > 0


def test_runner_sparse_wire_accounting(setup):
    """The driver's wire accounting under sparse: physical bytes == the
    logical counter scaled by (sparse per-message cost / dense model
    bytes) — the nnz-payload + bitmap wire format, not the dense ratio."""
    from repro.core.packing import make_pack_spec
    from repro.models.smallnets import make_classifier

    exp, data = setup
    r = _run(data, exp, sparse=SP)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, exp.dim, exp.n_classes)
        return p

    spec = make_pack_spec(jax.eval_shape(model_init, jax.random.PRNGKey(0)))
    x = spec.size
    per_msg = sparse_wire_model_bytes(CommConfig(codec="fp32"), x,
                                      SP.k_active(x))
    expect = float(r.comm_bytes) * per_msg / float(spec.model_bytes)
    np.testing.assert_allclose(float(r.wire_bytes), expect, rtol=1e-6)
    # and the physical bytes genuinely shrink vs the dense run
    dense = _run(data, exp)
    assert float(r.wire_bytes) < 0.3 * float(dense.wire_bytes)


# ------------------------------------------------- parity and composition


def test_density_one_is_bitexact_dense(setup):
    """density=1.0 routes through the dense code paths (static bypass):
    the run is BIT-identical to sparse=None, not merely close."""
    exp, data = setup
    a = _run(data, exp)
    b = _run(data, exp, sparse=SparseConfig(density=1.0))
    sa, sb = a.extras["state"], b.extras["state"]
    assert bool(jnp.array_equal(sa.centers, sb.centers))
    assert bool(jnp.array_equal(sa.u, sb.u))
    assert sa.mask is None
    assert bool(jnp.all(sb.mask == 1.0))


def test_sparse_loop_scan_bit_identical(setup):
    """The masked round is engine-invariant: Python-loop and scan-rolled
    runs produce bit-identical centers, mixtures, and mask streams, and
    the scan run stays one compile / one dispatch."""
    exp, data = setup
    a = _run(data, exp, sparse=SP)
    b = _run(data, exp, sparse=SP, scan_rounds=True)
    sa, sb = a.extras["state"], b.extras["state"]
    for f in ("centers", "u", "mask"):
        assert bool(jnp.array_equal(getattr(sa, f), getattr(sb, f))), f
    assert b.extras["n_compiles"] == 1
    assert b.extras["n_dispatches"] == 1
    # masks hold exact per-row counts after live RigL updates
    x = sa.mask.shape[-1]
    np.testing.assert_array_equal(np.asarray(sa.mask.sum(-1)),
                                  SP.k_active(x))


@pytest.mark.robustness
def test_sparse_full_composition_bit_identical(setup):
    """The whole stack at once — sparse masks × cohort subsampling ×
    ClientSystemModel (stragglers, availability, staleness decay) ×
    int8+EF wire codec — bit-identical between the loop and scan engines,
    with the scan engine still at one compile and one dispatch."""
    exp, data = setup
    het = ClientSystemModel(
        slow_fraction=0.25, slow_factor=4.0, time_budget=2.0, jitter=0.3,
        p_unavailable=0.1, staleness_gamma=0.9, seed=0,
    )
    base = dict(sparse=SP, cohort_size=6,
                comm=CommConfig(codec="int8", error_feedback=True),
                scenario=Scenario(system=het))
    a = _run(data, exp, **base)
    b = _run(data, exp, scan_rounds=True, **base)
    sa, sb = a.extras["state"], b.extras["state"]
    for f in ("centers", "u", "mask", "ef"):
        assert bool(jnp.array_equal(getattr(sa, f), getattr(sb, f))), f
    assert b.extras["n_compiles"] == 1
    assert b.extras["n_dispatches"] == 1
    x = sa.mask.shape[-1]
    np.testing.assert_array_equal(np.asarray(sa.mask.sum(-1)),
                                  SP.k_active(x))


@pytest.mark.parametrize("comm", [None, CommConfig(codec="int8",
                                                   error_feedback=True)])
def test_sparse_backend_parity(setup, comm):
    """The mask-aware Pallas kernels (slab-skipping matmul, masked fused
    dequant) reproduce the reference masked exchange exactly."""
    exp, data = setup
    kw = dict(sparse=SP) if comm is None else dict(sparse=SP, comm=comm)
    a = _run(data, exp, **kw)
    b = _run(data, exp, gossip_backend="pallas", **kw)
    sa, sb = a.extras["state"], b.extras["state"]
    np.testing.assert_allclose(np.asarray(sa.centers),
                               np.asarray(sb.centers), atol=1e-5)
    assert bool(jnp.array_equal(sa.mask, sb.mask))


def test_inactive_rows_keep_masks_bit_untouched():
    """The heterogeneity restore contract extends to masks: an inactive
    client's mask row comes through the round as the EXACT old bits (a
    where-select, not a recompute)."""
    from repro.core.fedspd import FedSPDState
    from repro.experiments.heterogeneity import restore_inactive

    key = jax.random.PRNGKey(0)
    n, x = 4, 32
    old_m = init_masks(key, n, x, SP)
    new_m = init_masks(jax.random.fold_in(key, 1), n, x, SP)

    def st(m):
        return FedSPDState(
            centers=jnp.zeros((2, n, x)), u=jnp.ones((n, 2)) / 2,
            z=jnp.zeros((n,), jnp.int32), round=jnp.int32(0), key=key,
            comm_bytes=jnp.float32(0), ef=None, mask=m,
        )

    axes = FedSPDState(centers=1, u=0, z=0, round=None, key=None,
                       comm_bytes=None, ef=None, mask=0)
    keep = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    out = restore_inactive(st(old_m), st(new_m), axes, keep > 0)
    got = np.asarray(out.mask)
    np.testing.assert_array_equal(got[1], np.asarray(old_m)[1])
    np.testing.assert_array_equal(got[3], np.asarray(old_m)[3])
    np.testing.assert_array_equal(got[0], np.asarray(new_m)[0])
    np.testing.assert_array_equal(got[2], np.asarray(new_m)[2])


def test_sparse_requires_packed_plane():
    with pytest.raises(ValueError, match="packed"):
        RunConfig(param_plane=False,
                  sparse=SparseConfig(density=0.5)).resolve_options()


def test_sparse_rejects_ppermute_backend(setup):
    exp, data = setup
    with pytest.raises((ValueError, SystemExit)):
        _run(data, exp, sparse=SP, gossip_backend="ppermute")


# -------------------------------------------------------------- telemetry


def test_telemetry_density_and_churn_streams(setup):
    """Sparse runs emit a constant density stream (the exact-count
    invariant, observable) and a churn stream that is zero on frozen
    rounds and positive exactly on RigL update rounds; dense runs emit
    NaN for both."""
    from repro.telemetry import TelemetryConfig

    exp, data = setup
    r = _run(data, exp, sparse=SP, telemetry=TelemetryConfig())
    st = r.telemetry["streams"]
    x = r.extras["state"].mask.shape[-1]
    np.testing.assert_allclose(np.asarray(st["density"]),
                               SP.k_active(x) / x, atol=1e-6)
    churn = np.asarray(st["mask_churn"])
    for rnd in range(exp.rounds):
        fires = rnd % SP.update_every == 0 and rnd > 0
        if fires:
            assert churn[rnd] > 0.0, rnd
        else:
            assert churn[rnd] == 0.0, rnd
    d = _run(data, exp, telemetry=TelemetryConfig())
    assert np.isnan(np.asarray(d.telemetry["streams"]["density"])).all()
    assert np.isnan(np.asarray(d.telemetry["streams"]["mask_churn"])).all()


# ------------------------------------------------------- bench trend gate


def test_compare_bench_harvests_nested_lanes():
    """Satellite guard: lane_medians must read rows that exist ONLY inside
    nested ``*_lanes`` payload lists (the sparse lanes' shape), so new
    lanes cannot dodge the regression gate by skipping ``results``."""
    path = (pathlib.Path(__file__).resolve().parents[1] / "benchmarks"
            / "compare_bench.py")
    spec = importlib.util.spec_from_file_location("compare_bench", path)
    cb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cb)

    payload = {
        "results": [{"lane": "a", "round_ms_median": 1.0}],
        "sparse_lanes": [{"lane": "fedspd/sparse_d20",
                          "round_ms_median": 2.0}],
        "comm_lanes": [{"lane": "fedspd/comm_int8", "round_ms": 3.0}],
    }
    med = cb.lane_medians(payload)
    assert med == {"a": 1.0, "fedspd/sparse_d20": 2.0,
                   "fedspd/comm_int8": 3.0}
    # a nested-only regression trips the gate
    new = {"results": [{"lane": "a", "round_ms_median": 1.0}],
           "sparse_lanes": [{"lane": "fedspd/sparse_d20",
                             "round_ms_median": 4.0}]}
    _, regressions = cb.compare(payload, new, threshold=0.25)
    assert regressions == ["fedspd/sparse_d20"]
