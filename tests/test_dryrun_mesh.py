"""Mesh/sharding tests — run in subprocesses with forced host device counts
so the main pytest process keeps its single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_small_mesh_fedspd_train_step_compiles_and_runs():
    """Not just lowering: allocate a tiny federation on an 8-device (2,4)
    mesh and RUN two FedSPD rounds, checking state invariants."""
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        import dataclasses
        import repro.configs.base as base
        from repro.configs.base import get_smoke_config
        from repro.launch.specs import build_dryrun
        from repro.launch.mesh import dp_axes

        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        base.INPUT_SHAPES["train_4k"] = dataclasses.replace(
            base.INPUT_SHAPES["train_4k"], seq_len=128, global_batch=4)
        cfg = get_smoke_config("olmo-1b").with_overrides(
            d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512)
        case = build_dryrun("olmo-1b", "train_4k", mesh, cfg_override=cfg)
        with mesh:
            fn = jax.jit(case.fn)
            lowered = fn.lower(*case.args)
            compiled = lowered.compile()
            # now RUN with real (tiny) data matching the specs
            def realize(s):
                if s.dtype == jnp.int32:
                    return jnp.zeros(s.shape, s.dtype)
                if s.dtype == jnp.uint32:
                    return jax.random.PRNGKey(0)
                return (jax.random.normal(jax.random.PRNGKey(1), s.shape)
                        * 0.02).astype(s.dtype)
            args = jax.tree.map(realize, case.args)
            state, batch = args
            # mixture coefficients must start on the simplex (1/S each)
            state = state._replace(u=jnp.full_like(state.u, 0.5))
            for _ in range(2):
                state, metrics = fn(state, batch)
            u = np.asarray(state.u)
            assert np.allclose(u.sum(-1), 1.0, atol=1e-3), u
            assert int(state.round) == 2
            leaves = jax.tree.leaves(state.centers)
            assert not any(np.isnan(np.asarray(l)).any() for l in leaves)
        print("MESH_RUN_OK")
    """))


def test_two_point_correction_matches_full_unroll():
    """The roofline two-point trip-count extrapolation agrees with a fully
    unrolled ground-truth compile within 5%."""
    out = _run("""
        import numpy as np, jax, dataclasses
        from jax.sharding import Mesh
        import repro.configs.base as base
        from repro.configs.base import get_smoke_config
        from repro.launch.specs import build_dryrun
        from repro.roofline import analysis as rl

        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        base.INPUT_SHAPES["train_4k"] = dataclasses.replace(
            base.INPUT_SHAPES["train_4k"], seq_len=1024, global_batch=4)
        cfg = get_smoke_config("olmo-1b").with_overrides(
            n_layers=6, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
            vocab=1024)
        vals = {}
        for u in (1, 2, 0):
            case = build_dryrun("olmo-1b", "train_4k", mesh,
                                cfg_override=cfg, scan_unroll=u)
            with mesh:
                c = jax.jit(case.fn).lower(*case.args).compile()
            ca = rl.cost_dict(c)  # list- vs dict-returning jaxlibs
            vals[u] = (ca["flops"], ca["bytes accessed"],
                       rl.collective_bytes(c.as_text())["total"])
        r = 5.0
        # collective bytes get a looser bound: XLA's collective-combiner
        # passes merge/split collectives differently at full unroll, so the
        # per-layer increment the two-point model assumes uniform is ~5% off
        tol = {"flops": 0.05, "bytes": 0.05, "coll": 0.08}
        for i, name in enumerate(("flops", "bytes", "coll")):
            est = rl.two_point(vals[1][i], vals[2][i], r)
            truth = vals[0][i]
            err = abs(est - truth) / truth
            print(f"{name} err {err:.4f}")
            assert err < tol[name], (name, est, truth)
        print("TWO_POINT_OK")
    """)
    assert "TWO_POINT_OK" in out


def test_serve_decode_step_with_sharded_cache():
    print(_run("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from jax.sharding import Mesh
        import repro.configs.base as base
        from repro.configs.base import get_smoke_config
        from repro.launch.specs import build_dryrun

        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        base.INPUT_SHAPES["decode_32k"] = dataclasses.replace(
            base.INPUT_SHAPES["decode_32k"], seq_len=256, global_batch=4)
        cfg = get_smoke_config("olmo-1b").with_overrides(
            d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512)
        case = build_dryrun("olmo-1b", "decode_32k", mesh, cfg_override=cfg)
        with mesh:
            compiled = jax.jit(case.fn).lower(*case.args).compile()
        print("DECODE_LOWER_OK")
    """))


def test_production_mesh_shapes():
    out = _run("""
        from repro.launch.mesh import make_production_mesh, dp_axes, n_chips
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
        assert n_chips(m1) == 256
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert n_chips(m2) == 512
        assert dp_axes(m2) == ("pod", "data")
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out
