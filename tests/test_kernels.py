"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
kernels/ref.py, executed with interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


_SLOW = pytest.mark.slow  # full interpret-mode sweeps run in the full lane;
# the first combo of each sweep stays in the fast lane as a smoke case


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,lq,lkv,hq,hkv,hd,window",
    [
        (2, 256, 256, 4, 2, 64, None),   # GQA causal
        pytest.param(1, 256, 256, 4, 4, 64, 128, marks=_SLOW),  # MHA window
        pytest.param(2, 128, 128, 8, 2, 32, None, marks=_SLOW),  # small hd
        pytest.param(1, 512, 512, 2, 1, 64, 256, marks=_SLOW),  # kv=1+window
        pytest.param(1, 384, 384, 4, 4, 128, None, marks=_SLOW),  # non-pow2
    ],
)
def test_flash_attention_sweep(b, lq, lkv, hq, hkv, hd, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, lq, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, lkv, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, lkv, hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=atol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,g,p,n,chunk",
    [
        (1, 128, 8, 2, 32, 16, 64),
        pytest.param(2, 256, 4, 1, 64, 64, 128, marks=_SLOW),
        pytest.param(2, 256, 4, 4, 64, 128, 128, marks=_SLOW),
        pytest.param(1, 512, 2, 1, 64, 64, 128, marks=_SLOW),
    ],
)
def test_ssd_scan_sweep(b, l, h, g, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1).astype(dtype)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, l, g, n), dtype)
    Cm = jax.random.normal(ks[4], (b, l, g, n), dtype)
    y, s = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    Bh = jnp.repeat(Bm, h // g, axis=2)
    Ch = jnp.repeat(Cm, h // g, axis=2)
    yr, sr = ref.ssd_scan_ref(x, dt, A, Bh, Ch)
    atol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol,
        rtol=atol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=atol,
                               rtol=atol)


def test_ssd_scan_initial_state():
    ks = jax.random.split(KEY, 6)
    b, l, h, p, n = 1, 128, 2, 32, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
    A = -jnp.exp(jax.random.uniform(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, l, h, n))
    Cm = jax.random.normal(ks[4], (b, l, h, n))
    s0 = jax.random.normal(ks[5], (b, h, p, n))
    y, s = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64, initial_state=s0)
    yr, sr = ref.ssd_scan_ref(x, dt, A, Bm, Cm, initial_state=s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)


@pytest.mark.parametrize("n_clients", [4, 8, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_sweep(n_clients, dtype):
    key = jax.random.PRNGKey(n_clients)
    w = jax.nn.softmax(jax.random.normal(key, (n_clients, n_clients)), axis=1)
    tree = {
        "a": jax.random.normal(key, (n_clients, 33, 7), dtype),
        "b": jax.random.normal(key, (n_clients, 5000), dtype),
        "c": jax.random.normal(key, (n_clients,), dtype),
    }
    out = ops.gossip_mix(w, tree)
    want = ref.gossip_mix_ref(w, tree)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(out[k], np.float32), np.asarray(want[k], np.float32),
            atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_gossip_mix_matches_fedspd_dense_path():
    """Kernel applied with the FedSPD Eq. (1) weight matrix == mix_dense."""
    from repro.core.gossip import GossipSpec, fedspd_weight_matrix, mix_dense
    from repro.graphs.topology import make_graph

    g = make_graph("er", 8, 3.0, seed=0)
    spec = GossipSpec.from_graph(g)
    key = jax.random.PRNGKey(3)
    s = jax.random.randint(key, (8,), 0, 2)
    tree = {"w": jax.random.normal(key, (8, 40))}
    wmat = fedspd_weight_matrix(spec, s)
    out = ops.gossip_mix(wmat, tree)
    want = mix_dense(spec, tree, s)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want["w"]),
                               atol=1e-5)


def test_moe_dispatch_modes_agree():
    """sort == cumsum exactly; grouped == global when capacity is generous
    (per-sequence grouping only changes the drop pattern)."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import apply_moe, init_moe

    key = jax.random.PRNGKey(0)
    p = init_moe(key, 32, 64, 8, "silu", jnp.float32)
    x = jax.random.normal(key, (4, 16, 32))
    o_cum, _ = apply_moe(p, x, top_k=2, capacity_factor=8.0, act="silu",
                         dispatch="cumsum")
    o_sort, _ = apply_moe(p, x, top_k=2, capacity_factor=8.0, act="silu",
                          dispatch="sort")
    o_grp, _ = apply_moe(p, x, top_k=2, capacity_factor=8.0, act="silu",
                         dispatch="grouped")
    np.testing.assert_allclose(np.asarray(o_cum), np.asarray(o_sort), atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_cum), np.asarray(o_grp), atol=1e-5)


@pytest.mark.parametrize("x,x_block", [(13, 8), (5000, 2048), (7, 32), (2048, 2048)])
def test_gossip_mix_flat_padding(x, x_block):
    """X not divisible by x_block exercises the ragged trailing block
    (Pallas edge masking — no host-side pad/crop copies) and x_block > X
    exercises the block clamp; both must equal the dense W@C."""
    from repro.kernels.gossip_mix import gossip_mix_flat

    key = jax.random.PRNGKey(x)
    n = 8
    w = jax.nn.softmax(jax.random.normal(key, (n, n)), axis=1)
    c = jax.random.normal(key, (n, x), jnp.float32)
    out = gossip_mix_flat(w, c, x_block=x_block, interpret=True)
    assert out.shape == (n, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w @ c), atol=1e-5)


def test_pallas_mix_fn_matches_reference_mix():
    """core/gossip.make_mix_fn('pallas') — the FedSPD gossip fast path —
    equals core/gossip.mix to fp32 tolerance on arbitrary trees/selections."""
    from repro.core.gossip import GossipSpec, make_mix_fn, mix
    from repro.graphs.topology import make_graph

    for seed in range(3):
        g = make_graph("er", 10, 4.0, seed=seed)
        spec = GossipSpec.from_graph(g, mode="dense")
        key = jax.random.PRNGKey(seed)
        tree = {
            "a": jax.random.normal(key, (10, 5, 3)),
            "b": jax.random.normal(key, (10, 17)),
            "c": jax.random.normal(key, (10,)),
        }
        s = jax.random.randint(key, (10,), 0, 2)
        ref_out = mix(spec, tree, s)
        pallas_out = make_mix_fn(spec, backend="pallas")(tree, s)
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(pallas_out[k]), np.asarray(ref_out[k]), atol=1e-5)


def test_make_mix_fn_rejects_unknown_backend():
    from repro.core.gossip import GossipSpec, make_mix_fn
    from repro.graphs.topology import make_graph

    spec = GossipSpec.from_graph(make_graph("er", 6, 3.0, seed=0))
    with pytest.raises(ValueError, match="unknown gossip backend"):
        make_mix_fn(spec, backend="cuda")
