"""Every baseline the paper compares against runs and learns on the mixture
task (decentralized + centralized variants via the experiment registry).

Slow lane: each case is a 40-round training run with accuracy thresholds;
the fast lane covers the same method plumbing via tests/test_registry.py.
"""
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import METHODS, run_method

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(
        n_clients=6, n_per_client=64, rounds=40, tau=3, batch=16,
        avg_degree=3.0, model="mlp", dim=16, n_classes=4,
    )
    data = make_mixture_classification(
        n_clients=exp.n_clients, n_clusters=2, n_per_client=exp.n_per_client,
        dim=exp.dim, n_classes=exp.n_classes, seed=3, noise=0.25,
    )
    return exp, data


# thresholds reflect the paper's observed ordering: personalized methods
# clearly beat chance; non-personalized FedAvg and pFedMe degrade on highly
# non-IID mixtures (paper Table 3: DFL-FedAvg ~= local; pFedMe fails to
# converge on CIFAR-100) — we only require they run, stay finite, and stay
# at/above chance level.
THRESH = {
    "fedspd": 0.55, "fedspd_permute": 0.55, "local": 0.45,
    "dfl_ifca": 0.3, "dfl_fedem": 0.26, "dfl_fedsoft": 0.26,
    "dfl_fedavg": 0.24, "cfl_fedavg": 0.24, "dfl_pfedme": 0.24,
}


@pytest.mark.parametrize("method", sorted(THRESH))
def test_method_runs_and_learns(setup, method):
    exp, data = setup
    res = run_method(method, data, exp, seed=0, eval_every=100)
    assert np.isfinite(res.mean_acc)
    assert res.mean_acc > THRESH[method], f"{method} acc {res.mean_acc}"
    assert res.acc_per_client.shape == (exp.n_clients,)
    if method != "local":
        assert res.comm_bytes > 0
    else:
        assert res.comm_bytes == 0


def test_fedspd_beats_nonpersonalized(setup):
    """The paper's core claim at test scale: FedSPD > DFL-FedAvg."""
    exp, data = setup
    a = run_method("fedspd", data, exp, seed=2, eval_every=100)
    b = run_method("dfl_fedavg", data, exp, seed=2, eval_every=100)
    # the ordering is the claim; the margin is deliberately modest — a
    # single-seed gap is sensitive to XLA-version float drift in the
    # jax-latest CI matrix row
    assert a.mean_acc > b.mean_acc + 0.05


def test_fedspd_permute_comm_not_higher_than_multicast(setup):
    exp, data = setup
    a = run_method("fedspd", data, exp, seed=1, eval_every=100)
    b = run_method("dfl_fedem", data, exp, seed=1, eval_every=100)
    # paper §6.3: FedEM transmits S models/round; FedSPD one -> ~half comm
    assert a.comm_bytes < 0.75 * b.comm_bytes


def test_all_methods_listed():
    assert set(METHODS) >= {
        "fedspd", "dfl_fedavg", "cfl_fedavg", "dfl_fedem", "cfl_fedem",
        "dfl_ifca", "cfl_ifca", "dfl_fedsoft", "cfl_fedsoft", "dfl_pfedme",
        "cfl_pfedme", "local",
    }
