"""param_plane=True for EVERY registry method id (ISSUE-3 tentpole).

The packed (S, N, X) / (N, X) parameter-plane engine (core/packing.py) now
backs all 13 method ids, not just FedSPD: init packs, the step runs flat
(scatter-added gradients, single-matmul gossip), personalize/evaluate
unpack at the API boundary. Each id must reproduce its pytree run to fp32
tolerance through init → rounds → personalize → eval, with identical comm
accounting; an adapter that has NOT opted in must be a hard ValueError,
never a silent pytree fallback.
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import (
    Method,
    register,
    registry,
    run_method,
    run_method_batch,
)

ALL_IDS = (
    "fedspd", "fedspd_permute", "local",
    "dfl_fedavg", "cfl_fedavg", "dfl_fedem", "cfl_fedem",
    "dfl_ifca", "cfl_ifca", "dfl_fedsoft", "cfl_fedsoft",
    "dfl_pfedme", "cfl_pfedme",
)

# fast lane keeps one id per adapter class (the cfl_ variants and
# fedspd_permute only change the mixing matrix / gossip wiring; fedspd's
# packed engine is already covered by tests/test_packing.py)
_FAST_IDS = {"local", "dfl_fedavg", "dfl_ifca", "dfl_fedsoft"}


@pytest.fixture(scope="module")
def setup():
    exp = PaperExpConfig(
        n_clients=5, n_per_client=32, rounds=3, tau=1, batch=8,
        avg_degree=3.0, model="mlp", dim=8, n_classes=3,
    )
    data = make_mixture_classification(
        n_clients=5, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=0, noise=0.3,
    )
    return exp, data


@pytest.mark.parametrize(
    "method",
    [m if m in _FAST_IDS else pytest.param(m, marks=pytest.mark.slow)
     for m in sorted(ALL_IDS)],
)
def test_param_plane_matches_pytree(setup, method):
    """Same seed, pytree vs packed plane: identical trajectory (same key
    streams, same batches, mathematically identical updates) to fp32
    tolerance — accuracies, mixture coefficients / hard assignments, and
    wire-byte accounting (original dtypes, never the fp32 plane's)."""
    exp, data = setup
    a = run_method(method, data, exp, seed=0, eval_every=100)
    b = run_method(method, data, exp, seed=0, eval_every=100,
                   param_plane=True)
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client, atol=1e-4)
    for k in ("u", "choice"):
        if k in a.extras:
            np.testing.assert_allclose(a.extras[k], b.extras[k], atol=1e-4)
    assert abs(a.comm_bytes - b.comm_bytes) <= 1e-6 * max(a.comm_bytes, 1.0)


def test_param_plane_batch_driver(setup):
    """Packed engine under the multi-seed vmapped driver: one compile,
    distinct finite per-seed results."""
    exp, data = setup
    rs = run_method_batch("dfl_fedavg", data, exp, seeds=(0, 1),
                          eval_every=2, options={"param_plane": True})
    assert len(rs) == 2
    assert all(np.isfinite(r.mean_acc) for r in rs)
    assert rs[0].extras["n_compiles"] == 1


@pytest.mark.slow
def test_param_plane_pallas_baseline_gossip(setup):
    """Baselines honour gossip_backend="pallas" on the plane: the static
    Metropolis average streams through kernels/gossip_mix and must match
    the reference einsum end to end."""
    exp, data = setup
    a = run_method("dfl_fedavg", data, exp, seed=0, eval_every=100,
                   param_plane=True)
    b = run_method("dfl_fedavg", data, exp, seed=0, eval_every=100,
                   param_plane=True, gossip_backend="pallas")
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client, atol=1e-5)
    c = run_method("dfl_fedem", data, exp, seed=0, eval_every=100,
                   param_plane=True)
    d = run_method("dfl_fedem", data, exp, seed=0, eval_every=100,
                   param_plane=True, gossip_backend="pallas")
    np.testing.assert_allclose(c.extras["u"], d.extras["u"], atol=1e-5)


def test_unsupported_param_plane_is_hard_error(setup):
    """A method whose adapter has not opted in must fail LOUDLY with its id
    in the message — the old behaviour silently fell back to pytree and
    misattributed benchmark results."""
    exp, data = setup

    class NoPlaneMethod(Method):
        name = "test_noplane"

        def init(self, ctx, key):  # pragma: no cover - never reached
            raise AssertionError("driver must reject before init")

    register(NoPlaneMethod())
    try:
        with pytest.raises(ValueError, match="test_noplane"):
            run_method("test_noplane", data, exp, seed=0, param_plane=True)
        with pytest.raises(ValueError, match="param_plane"):
            run_method_batch("test_noplane", data, exp, seeds=(0,),
                             options={"param_plane": True})
    finally:
        registry._REGISTRY.pop("test_noplane", None)


def test_all_builtin_methods_support_param_plane():
    """ISSUE-3 acceptance: param_plane is valid for all 13 registry ids."""
    from repro.experiments import get_method

    for m in ALL_IDS:
        assert get_method(m).supports_param_plane, m


def test_gossip_avg_stack_matches_reference():
    """The one-shot (S, N, X) stack mix (FedEM's exchange) equals the
    per-cluster reference einsum, on both backends."""
    from repro.baselines.common import gossip_avg, gossip_avg_stack

    key = jax.random.PRNGKey(0)
    w = jax.nn.softmax(jax.random.normal(key, (6, 6)), axis=1)
    plane = jax.random.normal(jax.random.PRNGKey(1), (3, 6, 41))
    want = jax.vmap(lambda c_s: gossip_avg(c_s, w))(plane)
    for backend in ("reference", "pallas"):
        got = gossip_avg_stack(plane, w, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
    with pytest.raises(ValueError, match="gossip backend"):
        gossip_avg_stack(plane, w, backend="nope")
