"""Scan-rolled round engine (``RunConfig(scan_rounds=True)``) and per-round
cohort subsampling (``RunConfig(cohort_size=K)``).

PR-6 acceptance criteria: (a) the lax.scan-rolled engine is bit-identical
to the historical Python-loop engine AND to the committed pre-refactor
seed fixture; (b) the whole experiment is ONE compiled program — jit cache
size 1 and a dispatch count independent of ``rounds``; (c) in-step
scenario dropout and schedule xs produce the identical mask/adjacency
stream under both engines; (d) cohort subsampling carries inactive
clients' rows bit-untouched and its wire bytes scale with K, not N.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import (
    RunConfig,
    Scenario,
    run_method,
    run_method_batch,
)
from repro.experiments.registry import build_context, get_method
from repro.experiments.runner import _cohort_indices, _cohort_step
from repro.graphs.topology import make_graph, rewire_schedule

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "fedspd_static_seed_curve.json")


@pytest.fixture(scope="module")
def setup():
    # MUST match the committed fixture's config block (test_scenarios.py)
    exp = PaperExpConfig(n_clients=6, n_per_client=32, rounds=4, tau=1,
                         batch=8, avg_degree=3.0, model="mlp", dim=8,
                         n_classes=3)
    data = make_mixture_classification(
        n_clients=6, n_clusters=2, n_per_client=32, dim=8, n_classes=3,
        seed=7, noise=0.3,
    )
    return exp, data


def _assert_same_run(a, b, exact=True):
    eq = (np.testing.assert_array_equal if exact
          else lambda x, y: np.testing.assert_allclose(x, y, atol=1e-6))
    eq(a.acc_per_client, b.acc_per_client)
    if "u" in a.extras:
        eq(np.asarray(a.extras["u"]), np.asarray(b.extras["u"]))
    assert [c[0] for c in a.curve] == [c[0] for c in b.curve]
    np.testing.assert_allclose([c[1] for c in a.curve],
                               [c[1] for c in b.curve], atol=1e-6)
    np.testing.assert_allclose(a.comm_bytes, b.comm_bytes, rtol=1e-9)


# ------------------------------------------------------------------
# engine parity: scan vs loop vs the committed fixture
# ------------------------------------------------------------------


def test_scan_matches_loop_and_committed_fixture(setup):
    """The scan engine reproduces the Python loop bit for bit, and BOTH
    still reproduce the committed pre-refactor seed curve."""
    exp, data = setup
    loop = run_method("fedspd", data, exp, seed=0,
                      cfg=RunConfig(eval_every=2))
    scan = run_method("fedspd", data, exp, seed=0,
                      cfg=RunConfig(eval_every=2, scan_rounds=True))
    _assert_same_run(loop, scan)
    with open(FIXTURE) as f:
        fx = json.load(f)
    for r in (loop, scan):
        np.testing.assert_allclose(r.acc_per_client, fx["acc_per_client"],
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(r.extras["u"]), fx["u"],
                                   atol=1e-6)
        assert [c[0] for c in r.curve] == [c[0] for c in fx["curve"]]
        np.testing.assert_allclose([c[1] for c in r.curve],
                                   [c[1] for c in fx["curve"]], atol=1e-6)
        np.testing.assert_allclose(r.comm_bytes, fx["comm_bytes"],
                                   rtol=1e-6)


@pytest.mark.parametrize("method", ["dfl_fedavg", "dfl_fedem", "local"])
def test_scan_matches_loop_baselines(setup, method):
    """Every registry method rolls into the scan unchanged — the round
    steps are pure in (state, train, key, lr)."""
    exp, data = setup
    cfg = RunConfig(eval_every=100)
    loop = run_method(method, data, exp, seed=0, cfg=cfg)
    scan = run_method(method, data, exp, seed=0,
                      cfg=dataclasses.replace(cfg, scan_rounds=True))
    _assert_same_run(loop, scan)


def test_scan_batch_matches_loop_batch(setup):
    exp, data = setup
    seeds = (0, 1)
    loop = run_method_batch("fedspd", data, exp, seeds=seeds,
                            cfg=RunConfig(eval_every=2))
    scan = run_method_batch("fedspd", data, exp, seeds=seeds,
                            cfg=RunConfig(eval_every=2, scan_rounds=True))
    assert scan[0].extras["n_compiles"] == 1
    for a, b in zip(loop, scan):
        _assert_same_run(a, b)


# ------------------------------------------------------------------
# one compile, one dispatch — independent of rounds
# ------------------------------------------------------------------


@pytest.mark.parametrize("rounds", [5, 50])
def test_scan_one_compile_one_dispatch(setup, rounds):
    """rounds=5 and rounds=50 each execute as ONE compiled program with
    ONE host dispatch (the round count only changes the scan length)."""
    exp, data = setup
    e = dataclasses.replace(exp, rounds=rounds)
    r = run_method("fedspd", data, e, seed=0,
                   cfg=RunConfig(eval_every=100, scan_rounds=True))
    assert r.extras["n_compiles"] == 1
    assert r.extras["n_dispatches"] == 1
    assert np.isfinite(r.mean_acc)


def test_loop_dispatch_count_scales_with_rounds(setup):
    """The historical loop engine reports one dispatch PER round — the
    contrast the scan engine's n_dispatches==1 is measured against."""
    exp, data = setup
    r = run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(eval_every=100))
    assert r.extras["n_compiles"] == 1
    assert r.extras["n_dispatches"] == exp.rounds


# ------------------------------------------------------------------
# scenario parity under the scan: in-step dropout, schedule xs
# ------------------------------------------------------------------


def test_scan_dropout_stream_matches_loop(setup):
    """Link dropout is a key-derived in-step Bernoulli draw
    (fold_in(key, round)), so the loop and the scan see the IDENTICAL
    mask stream — same comm bytes, same states."""
    exp, data = setup
    sc = Scenario(dropout=0.5, seed=1)
    loop = run_method("fedspd", data, exp, seed=0,
                      cfg=RunConfig(eval_every=100, scenario=sc))
    scan = run_method("fedspd", data, exp, seed=0,
                      cfg=RunConfig(eval_every=100, scenario=sc,
                                    scan_rounds=True))
    _assert_same_run(loop, scan)
    assert loop.comm_bytes > 0.0


def test_scan_schedule_rides_the_xs(setup):
    """A (rounds, N, N) rewire schedule feeds the scan as xs; the loop
    indexes the same stack host-side — identical runs, one compile."""
    exp, data = setup
    exp10 = dataclasses.replace(exp, rounds=10)
    sched = rewire_schedule("er", exp.n_clients, 3.0, rounds=10,
                            p_rewire=0.4, seed=2)
    sc = Scenario(graph_schedule=sched, dropout=0.2, seed=3)
    loop = run_method("fedspd", data, exp10, seed=0,
                      cfg=RunConfig(eval_every=100, scenario=sc))
    scan = run_method("fedspd", data, exp10, seed=0,
                      cfg=RunConfig(eval_every=100, scenario=sc,
                                    scan_rounds=True))
    _assert_same_run(loop, scan)
    assert scan.extras["n_compiles"] == 1


# ------------------------------------------------------------------
# cohort subsampling
# ------------------------------------------------------------------


def test_cohort_step_leaves_inactive_rows_untouched(setup):
    """The unit-level invariant: gather -> step at size K -> scatter must
    return every inactive client's centers/u/z rows BIT-untouched."""
    exp, data = setup
    m = get_method("fedspd")
    g = make_graph("er", exp.n_clients, 3.0, seed=0)
    ctx = build_context(data, exp, graph=g, seed=0,
                        options=RunConfig(param_plane=True).resolve_options())
    state = m.init(ctx, jax.random.PRNGKey(0))
    step = _cohort_step(m.make_step(ctx), m.cohort_axes(ctx, state))
    active = jnp.asarray([1, 3, 4])
    new, _ = jax.jit(step)(state, ctx.train, jax.random.PRNGKey(1),
                           jnp.float32(0.05),
                           jnp.asarray(g.adj, jnp.float32), active)
    inactive = np.asarray([0, 2, 5])
    np.testing.assert_array_equal(np.asarray(new.centers)[:, inactive],
                                  np.asarray(state.centers)[:, inactive])
    np.testing.assert_array_equal(np.asarray(new.u)[inactive],
                                  np.asarray(state.u)[inactive])
    np.testing.assert_array_equal(np.asarray(new.z)[inactive],
                                  np.asarray(state.z)[inactive])
    # ... while the active rows actually trained
    assert not np.array_equal(np.asarray(new.centers)[:, np.asarray(active)],
                              np.asarray(state.centers)[:, np.asarray(active)])


def test_cohort_indices_sorted_unique(setup):
    idx = np.asarray(_cohort_indices(jax.random.PRNGKey(3), 64, 16))
    assert idx.shape == (16,)
    assert (np.diff(idx) > 0).all()         # sorted, no duplicates
    assert idx.min() >= 0 and idx.max() < 64


def test_cohort_wire_bytes_scale_with_k_not_n(setup):
    """K=3 of N=6: tracked comm is bounded by the K-clique's directed
    edges (R * K * (K-1) messages) and lands strictly below the full run —
    dropped clients cost zero wire bytes."""
    exp, data = setup
    g = make_graph("er", exp.n_clients, 3.0, seed=0)
    base = RunConfig(eval_every=100, param_plane=True)
    full = run_method("fedspd", data, exp, graph=g, seed=0, cfg=base)
    coh = run_method("fedspd", data, exp, graph=g, seed=0,
                     cfg=dataclasses.replace(base, cohort_size=3))
    assert 0.0 < coh.comm_bytes < full.comm_bytes
    # model bytes backed out of the full run's exact accounting
    directed_edges = float(np.sum(g.adj)) - g.n
    model_bytes = full.comm_bytes / (exp.rounds * directed_edges)
    assert coh.comm_bytes <= exp.rounds * 3 * 2 * model_bytes + 1e-6


def test_cohort_full_size_matches_no_cohort(setup):
    """cohort_size=N gathers the identity cohort (sorted permutation of
    everything), so the run must match the cohort-free program."""
    exp, data = setup
    g = make_graph("er", exp.n_clients, 3.0, seed=0)
    base = RunConfig(eval_every=100, param_plane=True)
    a = run_method("fedspd", data, exp, graph=g, seed=0, cfg=base)
    b = run_method("fedspd", data, exp, graph=g, seed=0,
                   cfg=dataclasses.replace(base,
                                           cohort_size=exp.n_clients))
    np.testing.assert_allclose(a.acc_per_client, b.acc_per_client,
                               atol=1e-6)
    np.testing.assert_allclose(a.comm_bytes, b.comm_bytes, rtol=1e-6)


def test_cohort_scan_matches_loop(setup):
    """The cohort stream is fold_in(key, round)-derived, so both engines
    pick the identical cohorts."""
    exp, data = setup
    cfg = RunConfig(eval_every=2, param_plane=True, cohort_size=3)
    loop = run_method("fedspd", data, exp, seed=0, cfg=cfg)
    scan = run_method("fedspd", data, exp, seed=0,
                      cfg=dataclasses.replace(cfg, scan_rounds=True))
    _assert_same_run(loop, scan)
    assert scan.extras["n_dispatches"] == 1


def test_cohort_validation(setup):
    exp, data = setup
    with pytest.raises(ValueError, match="cohort subsampling"):
        run_method("dfl_fedavg", data, exp, seed=0,
                   cfg=RunConfig(cohort_size=3))
    with pytest.raises(ValueError, match="param_plane"):
        run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(cohort_size=3))
    with pytest.raises(ValueError, match="must be in 1..N"):
        run_method("fedspd", data, exp, seed=0,
                   cfg=RunConfig(param_plane=True,
                                 cohort_size=exp.n_clients + 1))
