"""Strong integration invariant: prefill + decode_step logits must match the
full teacher-forced forward at the same position, for every family."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.registry import build_model

pytestmark = pytest.mark.slow

FAMS = ["olmo-1b", "olmoe-1b-7b", "gemma3-1b", "mamba2-370m", "zamba2-1.2b",
        "whisper-base", "chameleon-34b"]


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg, attn_mode="ref")
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    b, l = 2, 16
    toks = jax.random.randint(key, (b, l + 1), 0, cfg.vocab)
    batch_full = {"tokens": toks}
    batch_prompt = {"tokens": toks[:, :l]}
    if cfg.family == "audio":
        d_enc = cfg.encoder_d_model or cfg.d_model
        frames = jax.random.normal(key, (b, cfg.encoder_frames or 16, d_enc)) * 0.1
        batch_full["frames"] = frames
        batch_prompt["frames"] = frames

    # teacher-forced logits at position l (i.e. after consuming token l)
    logits_full, _ = bundle.forward(params, batch_full)

    cache = bundle.init_cache(b, l + 4)
    cache = bundle.prefill(params, batch_prompt, cache)
    if int(cache["pos"]) == l:
        # feed token l as the decode input
        logits_dec, _ = bundle.decode_step(params, cache, toks[:, l : l + 1])
    else:
        # enc-dec prefill only fills cross-KV (pos stays 0): teacher-force
        # the decoder one token at a time through the self-attn cache
        step = jax.jit(bundle.decode_step)
        for t_pos in range(l + 1):
            logits_dec, cache = step(params, cache, toks[:, t_pos : t_pos + 1])

    got = np.asarray(logits_dec[:, 0], np.float32)
    want = np.asarray(logits_full[:, l], np.float32)
    # normalize: compare softmax distributions (logits can differ by const)
    gp = jax.nn.log_softmax(got[:, : cfg.vocab])
    wp = jax.nn.log_softmax(want[:, : cfg.vocab])
    np.testing.assert_allclose(np.asarray(gp), np.asarray(wp), atol=2e-2)
    # argmax agreement
    assert (np.argmax(got, -1) == np.argmax(want, -1)).mean() >= 0.9
