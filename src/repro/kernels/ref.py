"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these).

Each oracle is the straightforward O(full) materialization of what the
kernel computes with tiling + online algorithms; they are the ground truth
for the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """(B, Lq, Hq, hd) GQA attention with materialized (Lq, Lkv) scores."""
    b, lq, hq, hd = q.shape
    n_kv = k.shape[2]
    g = hq // n_kv
    qg = q.reshape(b, lq, n_kv, g, hd).astype(jnp.float32)
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
    scores = jnp.einsum("blkgd,bmkd->bkglm", qg, k32) / np.sqrt(hd)
    pos_q = jnp.arange(lq)
    pos_k = jnp.arange(k.shape[1])
    mask = jnp.ones((lq, k.shape[1]), dtype=bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        mask &= pos_q[:, None] - pos_k[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkglm,bmkd->blkgd", probs, v32)
    return out.reshape(b, lq, hq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, *, initial_state=None):
    """Sequential (per-token) SSD recurrence — the literal state-space form:

        S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_tᵀ ;  y_t = S_t C_t
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    f32 = jnp.float32
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), f32)

    def step(s, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        da = jnp.exp(dtt * A[None, :])  # (B,H)
        s = s * da[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt
        )
        y = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, y

    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(Bm.astype(f32), 1, 0),
        jnp.moveaxis(Cm.astype(f32), 1, 0),
    )
    s_final, ys = jax.lax.scan(step, initial_state.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, L, H, P)
    return y.astype(x.dtype), s_final


def gossip_mix_ref(w, c_tree):
    """C ← W·C over every (N, ...) leaf, fp32 accumulation."""
    def one(leaf):
        return jnp.einsum(
            "ij,j...->i...", w.astype(jnp.float32),
            leaf.astype(jnp.float32),
        ).astype(leaf.dtype)

    return jax.tree.map(one, c_tree)
