"""Jit'd public wrappers for the Pallas kernels.

On real TPU hardware set ``interpret=False`` (module-level default flips on
TPU backends automatically); this CPU container validates kernel bodies in
interpret mode. The model layers select kernels with ``attn_mode="pallas"``
/ ``use_pallas`` flags; the pure-JAX blocked paths remain the portable
fallback and the dry-run lowering path (Mosaic does not lower on the CPU
host platform).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gossip_mix as _gm
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_block", "kv_block")
)
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    q_block: int = 128, kv_block: int = 128):
    """GQA flash attention. q (B, Lq, Hq, hd); k/v (B, Lkv, Hkv, hd)."""
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, initial_state=None):
    """Mamba2 SSD chunked scan. Accepts grouped B/C (B, L, G, N) and expands
    groups to heads before the single-head kernel."""
    h = x.shape[2]
    g = Bm.shape[2]
    if g != h:
        rep = h // g
        Bm = jnp.repeat(Bm, rep, axis=2)
        Cm = jnp.repeat(Cm, rep, axis=2)
    return _ssd.ssd_scan(
        x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state,
        interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("x_block",))
def gossip_mix(w, c_tree, *, x_block: int = 2048):
    """FedSPD mixing C ← W·C over a pytree of (N, ...) leaves."""
    return _gm.gossip_mix_tree(
        w, c_tree, x_block=x_block, interpret=_default_interpret()
    )
