"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

TARGET: TPU v5e. Grid = (B, H, n_chunks) with the chunk axis innermost —
TPU executes the grid sequentially, so the (P, N) fp32 carried state lives
in VMEM scratch across chunk steps (the inter-chunk recurrence). Per grid
step the kernel evaluates the chunk's *dual quadratic form* with three MXU
matmuls (C·Bᵀ, L-masked scores · x, C · state) — chunk=128 keeps every
matmul dim ≥ the 128-wide MXU tile while the working set
(x (128, P) + B/C (128, N) + scores (128, 128) + state (P, N), fp32)
stays ≈ 0.25 MB for P=64, N=128 — far under VMEM.

Heads are grouped outside the kernel (ops.py repeats B/C from G groups to
H heads), so the kernel body is a single-head single-chunk program.

Validated on CPU via interpret=True against ssm.ssd_chunked
(tests/test_kernels.py sweeps (B, L, H, P, N) × chunk sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,    # (1, chunk, 1, P)
    dt_ref,   # (1, chunk, 1)
    a_ref,    # (1,)  decay rate for this head
    b_ref,    # (1, chunk, 1, N)
    c_ref,    # (1, chunk, 1, N)
    s0_ref,   # (1, 1, P, N) initial state for this (batch, head)
    y_ref,    # (1, chunk, 1, P) out
    sT_ref,   # (1, 1, P, N) out: final state
    state_ref,  # VMEM scratch (P, N) f32 — carried across chunk steps
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0].astype(jnp.float32)             # ()
    bm = b_ref[0, :, 0, :].astype(jnp.float32)   # (Q, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)   # (Q, N)

    da = dt * a                                  # (Q,)
    cum = jnp.cumsum(da)                         # (Q,)

    # intra-chunk dual form: L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(iq >= jq, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )                                            # (Q, Q)
    xdt = x * dt[:, None]                        # (Q, P)
    y_diag = jax.lax.dot_general(
        scores * lmat, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (Q, P)

    # off-diagonal: contribution of the carried state entering this chunk
    state_in = state_ref[...]                    # (P, N)
    y_off = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (Q, P)
    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # chunk state update: S <- exp(cum_Q) * S + sum_q exp(cum_Q - cum_q) dt_q x_q B_qᵀ
    decay_out = jnp.exp(cum[-1] - cum)           # (Q,)
    contrib = jax.lax.dot_general(
        x * (decay_out * dt)[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (P, N)
    state_ref[...] = jnp.exp(cum[-1]) * state_in + contrib

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        sT_ref[0, 0] = state_ref[...].astype(sT_ref.dtype)


def ssd_scan(
    x: jnp.ndarray,   # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)
    A: jnp.ndarray,   # (H,)
    Bm: jnp.ndarray,  # (B, L, H, N) — already head-expanded (ops.py)
    Cm: jnp.ndarray,  # (B, L, H, N)
    *,
    chunk: int = 128,
    initial_state: jnp.ndarray | None = None,  # (B, H, P, N)
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0
    n_chunks = l // chunk
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (b, h, n_chunks)
    y, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, initial_state)
    return y, s_final
