"""Pallas TPU flash attention (GQA, causal, sliding-window).

TARGET: TPU v5e MXU. Tiling: the grid is (B, Hq, n_q_blocks, n_kv_blocks)
with the kv axis innermost — TPU executes the grid sequentially, so the
(q_block, hd) fp32 accumulator and the (q_block,) running max / normalizer
live in VMEM scratch and persist across kv steps (the online-softmax
carry). Block shapes default to (128, 128): MXU-aligned (multiples of
128 on both matmul dims) and VMEM-sized — per grid step the working set is
q (128·hd) + k,v (128·hd each) + scores (128·128) + acc (128·hd) fp32
≈ 0.3 MB for hd=128, far under the ~16 MB VMEM budget, leaving room for
double-buffered pipelines.

Validated on CPU via interpret=True against models/attention.ref_attention
(tests/test_kernels.py sweeps shapes × dtypes × window settings).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, q_blk, 1, hd), (1, kv_blk, 1, hd)
    o_ref,                # (1, q_blk, 1, hd)
    acc_ref, m_ref, l_ref,  # VMEM scratch: (q_blk, hd) f32, (q_blk,) f32
    *,
    causal: bool,
    window: int | None,
    q_block: int,
    kv_block: int,
    n_kv: int,
    scale: float,
):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * q_block
    k_start = ki * kv_block

    # skip kv blocks that are entirely masked (pl.when guards the compute;
    # the grid step itself still issues, which is the TPU way)
    live = True
    if causal:
        live = k_start <= q_start + q_block - 1
    if window is not None:
        live = jnp.logical_and(
            live, q_start - (k_start + kv_block - 1) < window
        )

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (q_blk, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (kv_blk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (q_blk, kv_blk)

        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, dtype=bool)
        if causal:
            mask &= pos_q >= pos_k
        if window is not None:
            mask &= pos_q - pos_k < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Lq, Hq, hd)
    k: jnp.ndarray,  # (B, Lkv, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,  # CPU container: interpret-mode validation
) -> jnp.ndarray:
    b, lq, hq, hd = q.shape
    lkv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_block = min(q_block, lq)
    kv_block = min(kv_block, lkv)
    assert lq % q_block == 0 and lkv % kv_block == 0
    n_q, n_kv = lq // q_block, lkv // kv_block
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, n_kv=n_kv, scale=scale,
    )
    grid = (b, hq, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, hd),
                         lambda b_, h, qi, ki: (b_, qi, h, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h, qi, ki: (b_, ki, h // g, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda b_, h, qi, ki: (b_, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, hd),
                               lambda b_, h, qi, ki: (b_, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, lq, hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
