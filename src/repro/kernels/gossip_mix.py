"""Pallas TPU kernel for FedSPD's cluster-matched gossip mix C ← W·C.

TARGET: TPU v5e. The mixing weight matrix W (N×N, row-stochastic, built per
round from the adjacency and this round's cluster selections — Eq. (1)) is
tiny (N ≤ a few hundred clients → ≤ 0.25 MB fp32) and is kept whole in VMEM
for every grid step. The flattened parameter matrix C (N, X) with X up to
tens of billions is tiled along X: grid = (n_x_blocks,), each step loads a
(N, x_block) slab, does one (N×N)·(N×x_block) MXU matmul, and writes the
mixed slab. x_block = 2048 keeps the slab (N=128 → 1 MB bf16 in + 1 MB out
+ W) comfortably inside VMEM with room for double buffering, and the matmul
K-dim = N is zero-padded to 8/128 alignment by Mosaic.

This fuses FedSPD's neighbor averaging into a single streaming pass over
the parameters — the HBM-bound ideal (read C once, write C once).

Validated on CPU via interpret=True against core/gossip.mix_dense.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mix_kernel(w_ref, c_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)       # (N, N)
    c = c_ref[...].astype(jnp.float32)       # (N, x_block)
    o_ref[...] = jax.lax.dot_general(
        w, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def gossip_mix_flat(
    w: jnp.ndarray,  # (N, N) row-stochastic mixing weights
    c: jnp.ndarray,  # (N, X) flattened per-client parameters
    *,
    x_block: int = 2048,
    interpret: bool = True,
) -> jnp.ndarray:
    n, x = c.shape
    x_block = min(x_block, x)
    pad = (-x) % x_block
    if pad:
        c = jnp.pad(c, ((0, 0), (0, pad)))
    xp = c.shape[1]
    grid = (xp // x_block,)
    out = pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, x_block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, x_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, xp), c.dtype),
        interpret=interpret,
    )(w, c)
    return out[:, :x] if pad else out


def gossip_mix_tree(w: jnp.ndarray, c_tree, *, x_block: int = 2048,
                    interpret: bool = True):
    """Apply the mix to a pytree of (N, ...) leaves (flatten / unflatten)."""
    def one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        mixed = gossip_mix_flat(w, flat, x_block=x_block, interpret=interpret)
        return mixed.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, c_tree)
