"""Pallas TPU kernel for FedSPD's cluster-matched gossip mix C ← W·C.

TARGET: TPU v5e. The mixing weight matrix W (N×N, row-stochastic, built per
round from the adjacency and this round's cluster selections — Eq. (1)) is
tiny (N ≤ a few hundred clients → ≤ 0.25 MB fp32) and is kept whole in VMEM
for every grid step. The flattened parameter matrix C (N, X) with X up to
tens of billions is tiled along X: grid = (n_x_blocks,), each step loads a
(N, x_block) slab, does one (N×N)·(N×x_block) MXU matmul, and writes the
mixed slab. x_block = 2048 keeps the slab (N=128 → 1 MB bf16 in + 1 MB out
+ W) comfortably inside VMEM with room for double buffering, and the matmul
K-dim = N is zero-padded to 8/128 alignment by Mosaic. Interpret mode
(CPU validation) defaults to one whole-X block instead — there is no VMEM
to respect and each grid step costs ~100 µs of interpreter overhead.

This fuses FedSPD's neighbor averaging into a single streaming pass over
the parameters — the HBM-bound ideal (read C once, write C once).

Validated on CPU via interpret=True against core/gossip.mix_dense.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(w_ref, c_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)       # (N, N)
    c = c_ref[...].astype(jnp.float32)       # (N, x_block)
    o_ref[...] = jax.lax.dot_general(
        w, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def _plan_blocks(x: int, x_block: int | None, interpret: bool) -> int:
    """Block width for tiling the X axis.

    The X grid exists to bound VMEM residency on real TPUs; interpret
    mode (CPU validation / CI) has no VMEM and pays ~100 µs of
    interpreter overhead PER GRID STEP, so its default is one whole-X
    block. An explicit ``x_block`` is always honored (the multi-block
    path is exercised in tests via small explicit blocks).

    A requested ``x_block`` is re-planned into ``ceil(X / x_block)``
    equal-width blocks instead of always tiling at the full width: the
    trailing block's waste drops from up to ``x_block - 1`` columns to
    under one lane tile. Blocks stay 128-lane aligned whenever the
    caller's ``x_block`` is (the Mosaic tiling constraint); tiny or
    unaligned test sizes fall back to align=1. Non-dividing trailing
    blocks are handled by Pallas's edge masking — no host-side zero-pad
    / crop copies of the plane."""
    if x_block is None:
        x_block = x if interpret else 2048
    x_block = min(x_block, x)
    align = 128 if (x_block % 128 == 0 and x >= 128) else 1
    k = -(-x // x_block)          # number of grid steps
    per = -(-x // k)              # ceil(x / k) columns per step
    return -(-per // align) * align


def gossip_mix_flat(
    w: jnp.ndarray,  # (N, N) row-stochastic mixing weights
    c: jnp.ndarray,  # (N, X) flattened per-client parameters
    *,
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    n, x = c.shape
    x_block = _plan_blocks(x, x_block, interpret)
    grid = (-(-x // x_block),)
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, x_block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, x_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, x), c.dtype),
        interpret=interpret,
    )(w, c)


def _mix_stack_kernel(w_ref, c_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)       # (N, N)
    c = c_ref[...][0].astype(jnp.float32)    # (1, N, x_block) -> (N, x_block)
    o_ref[...] = jax.lax.dot_general(
        w, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)[None]


def gossip_mix_stack(
    w: jnp.ndarray,  # (N, N) mixing weights, shared by every stack
    c: jnp.ndarray,  # (S, N, X) packed center stacks
    *,
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    """Mix EVERY cluster stack of a packed (S, N, X) plane with the same
    weight matrix in ONE ``pallas_call``: grid = (S, x_blocks), each step
    one (N×N)·(N×x_block) MXU matmul on cluster s's slab. This is the
    FedEM/FedSoft-shaped exchange (all S models move every round) — the
    pytree layout pays S × n_leaves kernel launches for the same traffic."""
    s, n, x = c.shape
    x_block = _plan_blocks(x, x_block, interpret)
    return pl.pallas_call(
        _mix_stack_kernel,
        grid=(s, -(-x // x_block)),
        in_specs=[
            pl.BlockSpec((n, n), lambda si, i: (0, 0)),
            pl.BlockSpec((1, n, x_block), lambda si, i: (si, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, n, x_block), lambda si, i: (si, 0, i)),
        out_shape=jax.ShapeDtypeStruct((s, n, x), c.dtype),
        interpret=interpret,
    )(w, c)


def gossip_mix_tree(w: jnp.ndarray, c_tree, *, x_block: int | None = None,
                    interpret: bool = True):
    """Apply the mix to a pytree of (N, ...) leaves (flatten / unflatten).

    One ``pallas_call`` PER LEAF with ragged sub-block tails — kept as the
    compatibility path for pytree states. The packed parameter plane
    (core/packing.py) feeds ``gossip_mix_flat`` directly: exactly one call
    over the whole (N, X) buffer per round.
    """
    def one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        mixed = gossip_mix_flat(w, flat, x_block=x_block, interpret=interpret)
        return mixed.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, c_tree)


def _mix_sparse_kernel(w_ref, c_ref, a_ref, o_ref):
    """W·C on one slab, predicated on the slab's activity bit: a slab
    whose every column is dead for every client skips the MXU matmul (and
    the C read on real hardware) and writes zeros — the exact masked-mix
    result for an all-dead slab."""
    live = a_ref[0, 0] > 0

    @pl.when(live)
    def _mix():
        w = w_ref[...].astype(jnp.float32)       # (N, N)
        c = c_ref[...].astype(jnp.float32)       # (N, x_block)
        o_ref[...] = jax.lax.dot_general(
            w, c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[...] = jnp.zeros_like(o_ref[...])


def gossip_mix_sparse(
    w: jnp.ndarray,           # (N, N) mixing weights
    c: jnp.ndarray,           # (N, X) plane slab, ZERO on dead columns
    col_active: jnp.ndarray,  # (X,) float {0,1}: any client keeps column
    *,
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    """Mask-aware W·C for the sparse (DisPFL) exchange: the grid still
    tiles the full X axis (shapes stay static), but each slab carries a
    traced one-element activity flag — computed here as "any active column
    in the slab" from the column-activity vector — and ``pl.when``
    predication skips the matmul for all-dead 128-aligned slabs, writing
    exact zeros instead. Callers must pass ``c`` already projected onto
    the active support (masked values or the mask itself), which is what
    makes the skip exact rather than approximate."""
    n, x = c.shape
    if col_active.shape != (x,):
        raise ValueError(
            f"column activity {col_active.shape} does not match plane "
            f"width {x}"
        )
    x_block = _plan_blocks(x, x_block, interpret)
    k = -(-x // x_block)
    act = jnp.pad(col_active.astype(jnp.float32), (0, k * x_block - x))
    slab_act = (jnp.sum(act.reshape(k, x_block), axis=1) > 0)
    slab_act = slab_act.astype(jnp.float32).reshape(k, 1)
    return pl.pallas_call(
        _mix_sparse_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, x_block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, x_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, x), c.dtype),
        interpret=interpret,
    )(w, c, slab_act)


def _mix_dequant_kernel(w_ref, q_ref, sc_ref, o_ref, *, qblock: int):
    """Fused dequantize + mix on one (N, x_block) slab of the QUANTIZED
    plane: o = W · (q ⊙ repeat(scale, qblock)). The mix reads int8 values
    (plus one fp32 scale per ``qblock`` columns) from HBM — ~4× less read
    traffic than mixing a materialized fp32 decode."""
    w = w_ref[...].astype(jnp.float32)        # (M, N)
    q = q_ref[...].astype(jnp.float32)        # (N, x_block) int8 payload
    sc = sc_ref[...].astype(jnp.float32)      # (N, x_block // qblock)
    c = q * jnp.repeat(sc, qblock, axis=1)
    o_ref[...] = jax.lax.dot_general(
        w, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def gossip_mix_dequant(
    w: jnp.ndarray,       # (M, N) mixing weights (M == N for gossip;
                          # M == B request rows for mixture serving)
    q: jnp.ndarray,       # (N, Xp) int8 quantized plane (comm/codecs)
    scales: jnp.ndarray,  # (N, Xp // qblock) fp32 per-block scales
    *,
    qblock: int,                 # quantization block width along X
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    """Compressed exchange in ONE ``pallas_call``: dequantize the int8
    payload (per-block scales) and apply Eq. (1)'s W·C on each slab without
    ever materializing the fp32 decode in HBM.

    The weight matrix may be rectangular: gossip passes the square (N, N)
    round-mixing matrix; the serving layer (serve/server.py) passes a
    (B, S) batch of per-request mixture weights over the S-row cluster
    plane — Eq. (2) as the same fused kernel.

    ``q`` comes padded to a whole number of scale blocks
    (comm/codecs.quant_encode pads the tail with exact-zero quanta), so the
    grid tiles an X axis that is a multiple of ``qblock`` and the slab's
    scale columns align exactly — the caller crops the fp32 result back to
    the logical width X. Slab widths are planned like the other kernels
    here (equal-width, 128-lane aligned) then rounded up to a multiple of
    ``qblock`` so every scale belongs to exactly one slab."""
    n, xp = q.shape
    m = w.shape[0]
    if w.shape[1] != n:
        raise ValueError(f"weights {w.shape} do not match plane rows {n}")
    if xp % qblock != 0 or scales.shape != (n, xp // qblock):
        raise ValueError(
            f"quantized plane {q.shape} / scales {scales.shape} do not "
            f"tile with qblock={qblock}"
        )
    x_block = _plan_blocks(xp, x_block, interpret)
    x_block = min(-(-x_block // qblock) * qblock, xp)
    return pl.pallas_call(
        functools.partial(_mix_dequant_kernel, qblock=qblock),
        grid=(-(-xp // x_block),),
        in_specs=[
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((n, x_block), lambda i: (0, i)),
            pl.BlockSpec((n, x_block // qblock), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, x_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, xp), jnp.float32),
        interpret=interpret,
    )(w, q, scales)


def _mix_dequant_masked_kernel(w_ref, q_ref, sc_ref, m_ref, a_ref, o_ref,
                               *, qblock: int):
    """Fused dequantize + sender-mask + mix on one slab, predicated on the
    slab activity bit: o = W · (q ⊙ repeat(scale) ⊙ M). All-dead slabs
    write exact zeros without touching the payload."""
    live = a_ref[0, 0] > 0

    @pl.when(live)
    def _mix():
        w = w_ref[...].astype(jnp.float32)        # (M, N)
        q = q_ref[...].astype(jnp.float32)        # (N, x_block)
        sc = sc_ref[...].astype(jnp.float32)      # (N, x_block // qblock)
        m = m_ref[...].astype(jnp.float32)        # (N, x_block)
        c = q * jnp.repeat(sc, qblock, axis=1) * m
        o_ref[...] = jax.lax.dot_general(
            w, c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(o_ref.dtype)

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[...] = jnp.zeros_like(o_ref[...])


def gossip_mix_dequant_masked(
    w: jnp.ndarray,       # (M, N) mixing weights
    q: jnp.ndarray,       # (N, Xp) int8 quantized plane (comm/codecs)
    scales: jnp.ndarray,  # (N, Xp // qblock) fp32 per-block scales
    mask: jnp.ndarray,    # (N, X) float {0,1} per-sender masks, X <= Xp
    *,
    qblock: int,                 # quantization block width along X
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    """Masked variant of ``gossip_mix_dequant`` for the sparse exchange's
    numerator W·(M⊙Ĉ): the sender masks are applied IN the fused
    dequantize+mix pass (the fp32 decode still never exists in HBM), and
    slabs that are all-dead across every sender are skipped via the same
    traced activity bits as ``gossip_mix_sparse``. The mask is zero-padded
    to the quantized width; the caller crops the fp32 result to X."""
    n, xp = q.shape
    m_rows = w.shape[0]
    if w.shape[1] != n:
        raise ValueError(f"weights {w.shape} do not match plane rows {n}")
    if xp % qblock != 0 or scales.shape != (n, xp // qblock):
        raise ValueError(
            f"quantized plane {q.shape} / scales {scales.shape} do not "
            f"tile with qblock={qblock}"
        )
    if mask.ndim != 2 or mask.shape[0] != n or mask.shape[1] > xp:
        raise ValueError(
            f"mask {mask.shape} does not match quantized plane {q.shape}"
        )
    mask = jnp.pad(mask.astype(jnp.float32),
                   ((0, 0), (0, xp - mask.shape[1])))
    x_block = _plan_blocks(xp, x_block, interpret)
    x_block = min(-(-x_block // qblock) * qblock, xp)
    k = -(-xp // x_block)
    col = (jnp.sum(mask, axis=0) > 0).astype(jnp.float32)
    col = jnp.pad(col, (0, k * x_block - xp))
    slab_act = (jnp.sum(col.reshape(k, x_block), axis=1) > 0)
    slab_act = slab_act.astype(jnp.float32).reshape(k, 1)
    return pl.pallas_call(
        functools.partial(_mix_dequant_masked_kernel, qblock=qblock),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((m_rows, n), lambda i: (0, 0)),
            pl.BlockSpec((n, x_block), lambda i: (0, i)),
            pl.BlockSpec((n, x_block // qblock), lambda i: (0, i)),
            pl.BlockSpec((n, x_block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m_rows, x_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m_rows, xp), jnp.float32),
        interpret=interpret,
    )(w, q, scales, mask, slab_act)


def _mixture_dequant4_kernel(u_ref, p_ref, sc_ref, o_ref, *, qblock: int):
    """Fused nibble-unpack + dequantize + mixture matmul on one slab of
    the BIT-PACKED int4 cluster plane: o = U · (unpack4(p) ⊙ scales).
    The plane stays at ~0.5 byte/param in HBM — the serve path's hot
    format — and the fp32 cluster models never exist outside registers."""
    u = u_ref[...].astype(jnp.float32)        # (B, S)
    p = p_ref[...]                            # (S, x_block // 2) uint8
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    q = jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], 2 * p.shape[1])
    q = q - jnp.asarray(16, jnp.int8) * (q > 7).astype(jnp.int8)
    sc = sc_ref[...].astype(jnp.float32)      # (S, x_block // qblock)
    c = q.astype(jnp.float32) * jnp.repeat(sc, qblock, axis=1)
    o_ref[...] = jax.lax.dot_general(
        u, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def mixture_mix_dequant4(
    u: jnp.ndarray,       # (B, S) per-request mixture weights (Eq. (2))
    packed: jnp.ndarray,  # (S, Xp // 2) uint8 bit-packed int4 plane
    scales: jnp.ndarray,  # (S, Xp // qblock) fp32 per-block scales
    *,
    qblock: int,                 # quantization block width along X (even)
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    """Personalized-parameter materialization for a request batch in ONE
    ``pallas_call`` over the int4 BIT-PACKED cluster plane: each grid step
    unpacks a (S, x_block) slab from its paired-nibble uint8 image,
    dequantizes with the per-block scales, and contracts with the (B, S)
    mixture weights — Eq. (2) fused with the int4 decode, reading half a
    byte per parameter from HBM. Companion of ``gossip_mix_dequant``
    (which reads the int8-storage payload); the caller crops the (B, Xp)
    result back to the logical width X."""
    s, xh = packed.shape
    xp = 2 * xh
    if qblock % 2 or xp % qblock != 0 or scales.shape != (s, xp // qblock):
        raise ValueError(
            f"packed plane {packed.shape} / scales {scales.shape} do not "
            f"tile with an even qblock={qblock}"
        )
    b = u.shape[0]
    if u.shape != (b, s):
        raise ValueError(f"mixture weights {u.shape} != (B, {s})")
    x_block = _plan_blocks(xp, x_block, interpret)
    x_block = min(-(-x_block // qblock) * qblock, xp)
    if x_block % 2:  # nibble pairs must not straddle slabs
        x_block = min(2 * x_block, xp)
    return pl.pallas_call(
        functools.partial(_mixture_dequant4_kernel, qblock=qblock),
        grid=(-(-xp // x_block),),
        in_specs=[
            pl.BlockSpec((b, s), lambda i: (0, 0)),
            pl.BlockSpec((s, x_block // 2), lambda i: (0, i)),
            pl.BlockSpec((s, x_block // qblock), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, x_block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, xp), jnp.float32),
        interpret=interpret,
    )(u, packed, scales)


def gossip_mix_encoded(w: jnp.ndarray, enc: dict, *, qblock: int,
                       x_out: int, out_dtype, interpret: bool = True):
    """The fused compressed exchange both comm call sites share
    (core/gossip's FedSPD mix and baselines/common's W-average): one
    ``gossip_mix_dequant`` pass over the encoded payload
    (``{"q", "scale"}`` from comm/codecs.quant_encode), cropped back to
    the logical width and cast to the plane dtype."""
    mixed = gossip_mix_dequant(w, enc["q"], enc["scale"], qblock=qblock,
                               interpret=interpret)
    return mixed[..., :x_out].astype(out_dtype)


def gossip_mix_encoded_masked(w: jnp.ndarray, enc: dict, mask: jnp.ndarray,
                              *, qblock: int, x_out: int, out_dtype,
                              interpret: bool = True):
    """Sparse-exchange companion of ``gossip_mix_encoded``: the numerator
    W·(M⊙Ĉ) of the support-renormalized mix as one masked
    dequantize+mix pass over the encoded payload."""
    mixed = gossip_mix_dequant_masked(w, enc["q"], enc["scale"], mask,
                                      qblock=qblock, interpret=interpret)
    return mixed[..., :x_out].astype(out_dtype)


def _mix_dp_kernel(w_ref, co_ref, cn_ref, sc_ref, *refs, sigma: float):
    """Fused DP sanitize + mix on one (N, x_block) slab:
    o = W · (c_old + scale ⊙ (c_new − c_old) + σ·noise).
    ``refs`` is (nz_ref, o_ref) when σ > 0, else just (o_ref,) — clip-only
    rounds carry no noise operand at all (no wasted HBM traffic)."""
    o_ref = refs[-1]
    w = w_ref[...].astype(jnp.float32)        # (N, N)
    co = co_ref[...].astype(jnp.float32)      # (N, x_block)
    cn = cn_ref[...].astype(jnp.float32)
    sc = sc_ref[...].astype(jnp.float32)      # (N, 1) per-client clip scale
    c = co + sc * (cn - co)
    if sigma > 0.0:
        c = c + sigma * refs[0][...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(
        w, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def gossip_mix_fused_dp(
    w: jnp.ndarray,      # (N, N) row-stochastic mixing weights
    c_old: jnp.ndarray,  # (N, X) pre-round selected centers (packed plane)
    c_new: jnp.ndarray,  # (N, X) post-local-update centers
    scale: jnp.ndarray,  # (N, 1) per-client L2 clip scale (precomputed)
    noise,               # (N, X) standard Gaussian draw; None iff sigma == 0
    sigma: float,        # dp_clip * dp_noise_multiplier (static)
    *,
    x_block: int | None = None,  # default: 2048 compiled, whole-X interpret
    interpret: bool = True,
) -> jnp.ndarray:
    """DP round in a single streaming pass: clip·scale + noise + W·C fused
    into one ``pallas_call`` over the packed plane, so the parameters are
    read from and written to HBM exactly once. The per-client clip scale
    (one flat L2 norm) and the noise array are tiny / cheap by comparison
    and are produced outside the kernel. Clip-only DP (sigma == 0) passes
    ``noise=None`` and the kernel takes no noise operand."""
    n, x = c_old.shape
    sigma = float(sigma)
    assert c_new.shape == (n, x)
    assert (noise is None) == (sigma <= 0.0)
    scale = scale.reshape(n, 1)
    x_block = _plan_blocks(x, x_block, interpret)
    slab = pl.BlockSpec((n, x_block), lambda i: (0, i))
    in_specs = [
        pl.BlockSpec((n, n), lambda i: (0, 0)),
        slab,
        slab,
        pl.BlockSpec((n, 1), lambda i: (0, 0)),
    ]
    operands = [w, c_old, c_new, scale]
    if sigma > 0.0:
        assert noise.shape == (n, x)
        in_specs.append(slab)
        operands.append(noise)
    return pl.pallas_call(
        functools.partial(_mix_dp_kernel, sigma=sigma),
        grid=(-(-x // x_block),),
        in_specs=in_specs,
        out_specs=slab,
        out_shape=jax.ShapeDtypeStruct((n, x), c_old.dtype),
        interpret=interpret,
    )(*operands)
