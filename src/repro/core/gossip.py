"""FedSPD's cluster-matched gossip (paper Eq. (1)) + communication accounting.

Two execution paths compute the *same* mixing:

- ``dense``   (paper-faithful matrix form C_s <- W_s^t C_s): the data-
  dependent row-stochastic weight matrix is built on-device from the static
  adjacency and this round's cluster selections, then applied as an einsum
  over the client axis. Under pjit with the client axis sharded, XLA lowers
  this to an all-gather of the selected models (bytes ∝ N·X per client row).

- ``permute`` (beyond-paper, §Perf): the adjacency is edge-colored host-side
  (graphs/coloring.py); each color class is a partner-swap permutation.
  On a mesh the swap is one collective_permute per color (bytes ∝ deg·X).
  Since every neighbor appears in exactly one matching, accumulating
  (masked by cluster match) over colors reproduces Eq. (1) *exactly* —
  verified against the dense path in tests.

Cosine-similarity alignment (paper §6 "Client communications"): a received
model only joins the average if it actually resembles the receiver's current
center (cos ≥ threshold), which resolves label switching across clients.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.coloring import permute_schedule
from repro.graphs.topology import Graph

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    adj: np.ndarray  # augmented adjacency (diag 1)
    mode: str = "dense"  # dense | permute
    cos_align_threshold: float = -1.0  # -1 disables alignment filtering
    perms: tuple = ()  # permutations (edge coloring), for mode="permute"

    @staticmethod
    def from_graph(graph: Graph, mode: str = "dense",
                   cos_align_threshold: float = -1.0) -> "GossipSpec":
        perms = tuple(np.asarray(p) for p in permute_schedule(graph))
        return GossipSpec(
            adj=graph.adj, mode=mode,
            cos_align_threshold=cos_align_threshold, perms=perms,
        )


def _pairwise_cos(c_sel: PyTree) -> jnp.ndarray:
    """(N, N) cosine similarity between clients' selected centers."""
    flat = [jnp.reshape(l.astype(jnp.float32), (l.shape[0], -1))
            for l in jax.tree.leaves(c_sel)]
    # dot products accumulated leaf-by-leaf to avoid one giant concat
    gram = sum(f @ f.T for f in flat)
    norms = jnp.sqrt(jnp.clip(jnp.diagonal(gram), 1e-24))
    return gram / (norms[:, None] * norms[None, :])


def fedspd_weight_matrix(
    spec: GossipSpec, s: jnp.ndarray, c_sel: Optional[PyTree] = None,
    adj: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Row-stochastic W^t rows for the *selected* clusters.

    W[i, j] > 0 iff j in N[i] (closed) and s_j == s_i (and, if alignment is
    on, cos(c_j, c_i) ≥ threshold). Diagonal always included (Eq. (1) is a
    closed-neighborhood average).

    ``adj`` overrides the spec's static adjacency with THIS ROUND's traced
    (N, N) matrix (dynamic rewiring / Bernoulli link dropout — the scenario
    engine). Rows are renormalized over the surviving links, so a dropped
    edge simply vanishes from the average; ``adj=None`` reproduces the
    static-graph program bit for bit.

    The traced matrix may be WEIGHTED, not just 0/1: the heterogeneity
    engine (experiments/heterogeneity.py) decays a stale sender's column
    by ``gamma**staleness`` — those entries scale the pre-normalization
    weights and the row renormalization folds them into the mixture. A
    fully masked row (an unavailable client) collapses to e_i after the
    diagonal restore: the client keeps its own model. Weighted entries
    require the dense wiring — ``mix_permute`` reads the adjacency as a
    binary mask.
    """
    adj = jnp.asarray(spec.adj) if adj is None else adj.astype(jnp.float32)
    match = (s[None, :] == s[:, None]).astype(jnp.float32)
    w = adj * match
    if spec.cos_align_threshold > -1.0 and c_sel is not None:
        cos = _pairwise_cos(c_sel)
        w = w * (cos >= spec.cos_align_threshold).astype(jnp.float32)
    w = w.at[jnp.arange(w.shape[0]), jnp.arange(w.shape[0])].set(1.0)
    return w / jnp.sum(w, axis=1, keepdims=True)


def mix_dense(spec: GossipSpec, c_sel: PyTree, s: jnp.ndarray,
              adj: Optional[jnp.ndarray] = None) -> PyTree:
    """Paper-faithful C <- W C over the client axis."""
    # named_scope labels the exchange on profiler traces (the region runs
    # inside the jitted round program, where host annotations cannot see)
    with jax.named_scope("gossip/mix_dense"):
        w = fedspd_weight_matrix(spec, s, c_sel, adj=adj)

        def mix_leaf(leaf):
            return jnp.einsum(
                "ij,j...->i...", w.astype(jnp.float32),
                leaf.astype(jnp.float32)
            ).astype(leaf.dtype)

        return jax.tree.map(mix_leaf, c_sel)


def mix_permute(spec: GossipSpec, c_sel: PyTree, s: jnp.ndarray,
                adj: Optional[jnp.ndarray] = None) -> PyTree:
    """Edge-colored accumulate: one partner swap per color class.

    Single-host simulation uses take(); the launch layer swaps takes for
    jax.lax.ppermute when the client axis is mesh-sharded (same math).

    ``adj`` (traced per-round adjacency) must be a SUBGRAPH of the spec's
    static adjacency — the color schedule is built host-side from the
    union graph, and each round's traced matrix only masks edges off
    (dropout / the inactive edges of a rewire schedule).
    """
    with jax.named_scope("gossip/mix_permute"):
        n = s.shape[0]
        cos = None
        if spec.cos_align_threshold > -1.0:
            cos = _pairwise_cos(c_sel)

        acc = jax.tree.map(lambda l: l.astype(jnp.float32), c_sel)
        cnt = jnp.ones((n,), jnp.float32)
        idx = jnp.arange(n)
        for perm in spec.perms:
            p = jnp.asarray(perm)
            partner_s = jnp.take(s, p)
            match = (partner_s == s) & (p != idx)
            if adj is not None:
                match &= adj[idx, p] > 0
            if cos is not None:
                match &= cos[idx, p] >= spec.cos_align_threshold
            mf = match.astype(jnp.float32)

            def add(a, l):
                recv = jnp.take(l, p, axis=0).astype(jnp.float32)
                m = mf.reshape((-1,) + (1,) * (l.ndim - 1))
                return a + m * recv

            acc = jax.tree.map(add, acc, c_sel)
            cnt = cnt + mf
        inv = 1.0 / cnt

        def norm(a, l):
            return (a * inv.reshape((-1,) + (1,) * (a.ndim - 1))
                    ).astype(l.dtype)

        return jax.tree.map(norm, acc, c_sel)


def mix(spec: GossipSpec, c_sel: PyTree, s: jnp.ndarray,
        adj: Optional[jnp.ndarray] = None) -> PyTree:
    if spec.mode == "dense":
        return mix_dense(spec, c_sel, s, adj=adj)
    if spec.mode == "permute":
        return mix_permute(spec, c_sel, s, adj=adj)
    raise ValueError(f"unknown gossip mode {spec.mode!r}")


MIX_BACKENDS = ("reference", "pallas", "ppermute")


def make_mix_fn(spec: GossipSpec, backend: str = "reference", *,
                plane: bool = False, mesh=None, comm=None):
    """Gossip backend selector: a ``mix_fn(c_sel, s)`` for FedSPD's round
    step (core/fedspd.make_round_step).

    Every returned mix (all three backends, comm-aware or not) additionally
    accepts ``adj=``: THIS ROUND's traced (N, N) adjacency, overriding the
    spec's static matrix — the scenario engine's dynamic-topology hook
    (experiments/scenarios.py). Dense/Pallas backends accept arbitrary
    adjacencies; permute/ppermute wiring requires a subgraph of the static
    union (the edge-color schedule is host-side), with the traced matrix
    masking inactive edges.

    ``comm`` (comm/codecs.CommConfig) composes the compressed exchange
    decode∘mix∘encode around every backend. ``codec="fp32"`` (or
    ``comm=None``) keeps the uncompressed per-backend paths documented
    below bit-exactly; any other codec requires the packed plane
    (``plane=True``) and returns a COMM-AWARE mix — signature
    ``mix_fn(c_sel, s, key, ef) -> (mixed, ef')`` with
    ``mix_fn.comm_aware = True`` — so the round step can thread the rng
    key and per-client error-feedback residual through the channel: the
    reference backend then mixes the jnp-decoded values (the parity
    oracle), the Pallas backend feeds the encoded payload to the fused
    ``kernels/gossip_mix.gossip_mix_dequant`` kernel (dequantize + W·C in
    ONE ``pallas_call`` whose HBM read side is the int8 plane; ``topk``
    decodes outside and streams the dense mix, still one call), and the
    ppermute backend ships the ENCODED payload over the collective edges
    (launch/steps.py) with receivers dequantizing locally.

    The uncompressed backends:

    - ``reference``: the pure-jnp paths above (dense einsum or edge-colored
      permute schedule, per ``spec.mode``). Polymorphic over pytree and
      packed (N, X) inputs (a bare array is a one-leaf pytree).
    - ``pallas``: build the Eq. (1) weight matrix, then stream C <- W·C
      through the Pallas TPU kernel (kernels/gossip_mix) — one HBM pass over
      the flattened parameters. Interpret mode on CPU hosts, compiled Mosaic
      on TPU (kernels/ops convention). With ``plane=True`` the input is the
      packed (N, X) parameter plane and the backend issues exactly ONE
      ``pallas_call`` per mix (asserted in tests/test_packing.py); DP rounds
      additionally expose ``mix_fn.fused_dp`` — the fused clip·scale + W·C
      kernel — so a sanitized exchange stays a single HBM pass.
    - ``ppermute``: the launch/steps.py shard_map edge-colored
      ``lax.ppermute`` schedule (one collective permute per color class,
      bytes ∝ deg·X per client instead of the dense all-gather's N·X).
      Needs EXACTLY one client per mesh row: pass a mesh whose
      ("pod","data") rows number exactly N, or leave ``mesh=None`` to
      auto-build an (N, 1) ("data","model") mesh from visible devices
      (raises if fewer than N are visible — force with
      --xla_force_host_platform_device_count on CPU hosts; an oversized
      mesh is NOT valid, the shard_map specs divide the client axis by
      the row count). Parity with the reference path is asserted in tests.
    """
    compressing = comm is not None and comm.codec != "fp32"
    if compressing and not plane:
        raise ValueError(
            f"comm codec {comm.codec!r} operates on packed (N, X) plane "
            "slices; build the mix with plane=True (run_method enables "
            "param_plane automatically when comm is set)"
        )
    if compressing and backend != "ppermute":
        # ppermute handles its own comm wiring below (the schedule ships
        # the encoded payload); reference/pallas get dedicated comm mixes
        return _make_comm_mix_fn(spec, backend, comm=comm)
    if backend in ("reference", None):
        return lambda c_sel, s, adj=None: mix(spec, c_sel, s, adj=adj)
    if backend == "pallas":
        from repro.kernels.gossip_mix import (
            gossip_mix_flat,
            gossip_mix_fused_dp,
            gossip_mix_tree,
        )

        interpret = jax.default_backend() != "tpu"

        if plane:
            from repro.kernels.gossip_mix import gossip_mix_sparse

            def mix_pallas(c_sel, s, adj=None):
                w = fedspd_weight_matrix(spec, s, c_sel, adj=adj)
                return gossip_mix_flat(
                    w, c_sel, interpret=interpret
                ).astype(c_sel.dtype)

            def fused_dp(c_old, c_new, scale, noise, sigma, s, adj=None):
                # weight matrix from selections only — cos alignment would
                # need the sanitized values this kernel is about to build
                w = fedspd_weight_matrix(spec, s, None, adj=adj)
                return gossip_mix_fused_dp(
                    w, c_old, c_new, scale, noise, sigma,
                    interpret=interpret,
                ).astype(c_old.dtype)

            def sparse_matmul(w, v, col_active):
                # the sparse exchange's W·(M⊙·) products: all-dead
                # 128-aligned slabs are skipped via traced activity bits
                return gossip_mix_sparse(
                    w, v, col_active, interpret=interpret
                ).astype(v.dtype)

            if spec.cos_align_threshold <= -1.0:
                mix_pallas.fused_dp = fused_dp
            mix_pallas.sparse_matmul = sparse_matmul
            return mix_pallas

        def mix_pallas(c_sel, s, adj=None):
            w = fedspd_weight_matrix(spec, s, c_sel, adj=adj)
            return gossip_mix_tree(w, c_sel, interpret=interpret)

        return mix_pallas
    if backend == "ppermute":
        if spec.cos_align_threshold > -1.0:
            raise ValueError(
                "ppermute backend does not implement cosine-alignment "
                "filtering; use the reference or pallas backend"
            )
        from repro.launch.steps import make_ppermute_gossip_mix

        n = spec.adj.shape[0]
        if mesh is None:
            devices = jax.devices()
            if len(devices) < n:
                raise RuntimeError(
                    "ppermute backend needs one device per client "
                    f"({n} clients, {len(devices)} devices visible) — run "
                    "under a mesh, or force host devices with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count"
                )
            mesh = jax.sharding.Mesh(
                np.asarray(devices[:n]).reshape(n, 1), ("data", "model")
            )
        return make_ppermute_gossip_mix(
            spec, mesh, replicate_model_dims=True, comm=comm
        )
    raise ValueError(
        f"unknown gossip backend {backend!r}; expected one of {MIX_BACKENDS}"
    )


def _make_comm_mix_fn(spec: GossipSpec, backend: str, *, comm):
    """The compressed-exchange variants of the reference and Pallas
    backends (see ``make_mix_fn``; ppermute wires its own comm inside
    launch/steps.make_ppermute_gossip_mix). Returned fns carry
    ``comm_aware = True`` and the ``(c_sel, s, key, ef) -> (mixed, ef')``
    signature; the channel is bound lazily to the plane width at trace
    time (same static metadata wherever it is built —
    comm/codecs.Channel is pure)."""
    from repro.comm.codecs import make_channel

    needs_hat = spec.cos_align_threshold > -1.0

    if backend in ("reference", None):
        def mix_comm(c_sel, s, key, ef, adj=None):
            ch = make_channel(comm, c_sel.shape[-1])
            x_hat, ef = ch.roundtrip(c_sel, key, ef)
            return mix(spec, x_hat, s, adj=adj).astype(c_sel.dtype), ef

        mix_comm.comm_aware = True
        return mix_comm

    if backend == "pallas":
        from repro.kernels.gossip_mix import (
            gossip_mix_encoded,
            gossip_mix_encoded_masked,
            gossip_mix_flat,
            gossip_mix_sparse,
        )

        interpret = jax.default_backend() != "tpu"

        def mix_comm(c_sel, s, key, ef, adj=None):
            x = c_sel.shape[-1]
            ch = make_channel(comm, x)
            if ch.fused:
                enc, x_hat, ef = ch.encode_stream(c_sel, key, ef,
                                                  need_hat=needs_hat)
                w = fedspd_weight_matrix(spec, s,
                                         x_hat if needs_hat else None,
                                         adj=adj)
                return gossip_mix_encoded(
                    w, enc, qblock=comm.block, x_out=x,
                    out_dtype=c_sel.dtype, interpret=interpret,
                ), ef
            x_hat, ef = ch.roundtrip(c_sel, key, ef)
            w = fedspd_weight_matrix(spec, s, x_hat if needs_hat else None,
                                     adj=adj)
            mixed = gossip_mix_flat(w, x_hat, interpret=interpret)
            return mixed.astype(c_sel.dtype), ef

        def sparse_matmul(w, v, col_active):
            return gossip_mix_sparse(
                w, v, col_active, interpret=interpret
            ).astype(v.dtype)

        def sparse_dequant(w, enc, mask):
            # W·(M⊙Ĉ) straight off the encoded payload: the fused masked
            # dequantize+mix kernel, cropped to the mask's logical width
            return gossip_mix_encoded_masked(
                w, enc, mask, qblock=comm.block, x_out=mask.shape[-1],
                out_dtype=jnp.float32, interpret=interpret,
            )

        mix_comm.comm_aware = True
        mix_comm.sparse_matmul = sparse_matmul
        mix_comm.sparse_dequant = sparse_dequant
        return mix_comm

    raise ValueError(
        f"unknown gossip backend {backend!r}; expected one of {MIX_BACKENDS}"
    )


# --------------------------------------------------------------------------
# Communication accounting (paper §6.3)
# --------------------------------------------------------------------------


def round_comm_bytes(
    spec: GossipSpec, s: jnp.ndarray, model_bytes: int, *,
    point_to_point: bool = True, models_per_client: int = 1,
    adj: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Bytes transmitted this round across all clients.

    multicast: every client broadcasts its updated model(s) once per
    neighbor-link regardless of match (FedAvg/FedSoft semantics; FedEM has
    models_per_client=S). point_to_point FedSPD: a client sends its model
    only to neighbors that selected the same cluster (paper §6.3).

    ``adj`` (traced per-round adjacency — the scenario engine) replaces the
    static topology in the link count, so a dropped or rewired-away edge
    costs exactly zero wire bytes this round. The traced matrix may carry
    fractional stale-gossip weights (experiments/heterogeneity.py) — the
    accounting BINARIZES it: a link either ships a full model or nothing,
    and a timed-out / unavailable client (zero row and column) is charged
    exactly zero bytes.
    """
    # the eye is sized from the EFFECTIVE adjacency, not the spec: cohort
    # subsampling passes the (K, K) minor of the round's graph
    adj = (jnp.asarray(spec.adj) if adj is None
           else (adj > 0).astype(jnp.float32))
    # zero the diagonal MULTIPLICATIVELY: an inactive client's masked-out
    # diagonal is already 0, and subtracting the eye would charge it -1
    adj = adj * (1.0 - jnp.eye(adj.shape[0]))
    if point_to_point:
        match = (s[None, :] == s[:, None]).astype(jnp.float32)
        links = jnp.sum(adj * match)
    else:
        links = jnp.sum(adj)
    # float literals: model_bytes exceeds int32 range for ≥1B-param models
    return links * float(model_bytes) * float(models_per_client)


def consensus_distance(c_stack: PyTree) -> jnp.ndarray:
    """Theorem 5.10's E_t: mean squared distance of clients' centers to the
    client-average, summed over pytree leaves. c_stack leaves: (N, ...)."""
    def per_leaf(l):
        l32 = l.astype(jnp.float32)
        mean = jnp.mean(l32, axis=0, keepdims=True)
        return jnp.sum(jnp.square(l32 - mean)) / l.shape[0]

    return sum(jax.tree.leaves(jax.tree.map(per_leaf, c_stack)))
