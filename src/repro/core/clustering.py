"""FedSPD Step 4: data clustering + mixture-coefficient estimation.

Each client labels every local data point with the cluster whose current
center yields the lowest loss (paper Algorithm 1, DataClustering), then sets
u_{i,s} to the fraction of points labeled s. Evaluation of S centers over M
points is a vmapped forward — batched over (S,) so the matrix units stay
busy; ``chunk`` bounds peak memory for large local datasets.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def assign_clusters(
    per_example_loss: Callable,  # (params, batch) -> (M,)
    centers_i: PyTree,  # leaves (S, ...) one client's centers
    batch_i: dict,      # leaves (M, ...) one client's data
    chunk: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (z (M,) argmin assignments, losses (S, M))."""
    def loss_for_center(c):
        if chunk is None:
            return per_example_loss(c, batch_i)
        m = jax.tree.leaves(batch_i)[0].shape[0]
        assert m % chunk == 0, (m, chunk)
        chunked = jax.tree.map(
            lambda x: x.reshape((m // chunk, chunk) + x.shape[1:]), batch_i
        )
        return jax.lax.map(lambda b: per_example_loss(c, b), chunked).reshape(m)

    losses = jax.vmap(loss_for_center)(centers_i)  # (S, M)
    return jnp.argmin(losses, axis=0), losses


def mixture_coefficients(z: jnp.ndarray, s_clusters: int,
                         floor: float = 1e-3) -> jnp.ndarray:
    """u_{i,s}: fraction of points assigned to each cluster, floored so no
    cluster's selection probability collapses to exactly zero early on
    (keeps Assumption 5.6's bounded-error regime reachable)."""
    counts = jnp.sum(jax.nn.one_hot(z, s_clusters), axis=0)
    u = counts / jnp.maximum(jnp.sum(counts), 1.0)
    u = jnp.maximum(u, floor)
    return u / jnp.sum(u)


def cluster_all_clients(
    per_example_loss: Callable,
    centers: PyTree,  # leaves (S, N, ...)
    data: dict,       # leaves (N, M, ...)
    s_clusters: int,
    chunk: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """vmap over clients. Returns (z (N, M), u (N, S))."""
    def one_client(centers_i, data_i):
        z, _ = assign_clusters(per_example_loss, centers_i, data_i, chunk)
        return z, mixture_coefficients(z, s_clusters)

    centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), centers)  # (N,S,...)
    return jax.vmap(one_client)(centers_nc, data)


def clustering_accuracy(z: jnp.ndarray, z_true: jnp.ndarray,
                        s_clusters: int) -> jnp.ndarray:
    """Best-permutation agreement between inferred and true cluster labels
    (label switching makes raw agreement meaningless). For the small S used
    here (2–4) we check all permutations."""
    import itertools

    accs = []
    for perm in itertools.permutations(range(s_clusters)):
        mapped = jnp.asarray(perm)[z]
        accs.append(jnp.mean((mapped == z_true).astype(jnp.float32)))
    return jnp.max(jnp.stack(accs))
