"""FedSPD: Soft-clustering Personalized Decentralized FL (paper Algorithm 1).

Round structure (Section 4):
  1. LocalUpdate       — each client i samples s_i ~ Categorical(u_i) and
                         runs τ SGD steps on c_{i,s_i} using data currently
                         assigned to cluster s_i;
  2. ParameterExchange — broadcast (s_i, c_{i,s_i}) to graph neighbors;
  3. ParameterUpdate   — closed-neighborhood average over matching
                         selections (Eq. (1); core/gossip.py);
  4. DataClustering    — relabel every local point by min-loss center and
                         recompute u (core/clustering.py).
FinalPhase (Eq. (2)): x_i = Σ_s u_{i,s} c_{i,s}, then τ_final local epochs
on all of D_i.

Everything is a single jitted step vmapped over the client axis, so the same
code runs the paper-scale CPU experiments and the mesh-sharded production
configs (launch/ shards the client axis and model dims).

Two data regimes:
- ``full``   (paper-faithful): persistent per-point assignments z over each
  client's entire local dataset; clustering re-evaluates all M points.
- ``stream`` (production): each round consumes a fresh batch; assignments
  are computed per-batch, training uses a cluster-masked loss, and u is
  updated as an EMA of batch assignment fractions. Used by launch/train.

Two parameter representations (``make_round_step(pack_spec=...)``):
- pytree (reference): ``state.centers`` has leaves (S, N, ...); every
  cross-client stage walks the tree leaf-by-leaf.
- packed plane (core/packing.py): ``state.centers`` is ONE (S, N, X)
  fp32 buffer; gather/scatter are single-array indexing, DP clip+noise is
  one flat L2 norm + fused scale-and-noise over (N, X), gossip mixes the
  whole plane in one pass (exactly one ``pallas_call`` on the Pallas
  backend), consensus and Eq. (2) are flat reductions. Models re-enter
  pytree form only where gradients/forwards need model structure (the
  local-SGD inner loop and the clustering forward) and at the API
  boundary (init, eval, checkpoint). Parity with the pytree path is
  asserted in tests/test_packing.py.

The round step is pure in (state, train, key, lr) with static shapes, so
the experiment drivers can either dispatch it once per round (the Python
loop engine) or trace it as the body of a whole-experiment ``lax.scan``
(``RunConfig(scan_rounds=True)``: all R rounds in one compiled program,
adjacency schedule as scan xs, metric curve as scan ys) — both engines
produce bit-identical states because the step draws nothing from host
state (tests/test_scan_rounds.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.clustering import cluster_all_clients, mixture_coefficients
from repro.core.gossip import (
    GossipSpec,
    consensus_distance,
    fedspd_weight_matrix,
    mix,
    round_comm_bytes,
)
from repro.core.packing import PackSpec, pack, unpack
from repro.core.sparse import column_activity, maybe_update_mask
from repro.data.pipeline import client_batches, client_uniform_batches
from repro.optim.sgd import Optimizer, sgd
from repro.utils.pytree import (
    tree_bytes,
    tree_weighted_sum,
)

PyTree = Any


class FedSPDState(NamedTuple):
    centers: PyTree      # leaves (S, N, ...): client i's estimate of center s
    u: jnp.ndarray       # (N, S) mixture coefficients
    z: jnp.ndarray       # (N, M) per-point assignments ("full" regime)
    round: jnp.ndarray   # () int32
    key: jax.Array
    comm_bytes: jnp.ndarray  # () float32 cumulative LOGICAL bytes
    ef: Any = None       # (N, X) error-feedback residual (comm/codecs);
    #                      None (an empty pytree subtree) unless the run
    #                      uses a compressing codec with error_feedback
    mask: Any = None     # (N, X) float {0,1} per-client sparse masks
    #                      (core/sparse; DisPFL) — None unless the run
    #                      carries a SparseConfig


@dataclasses.dataclass(frozen=True)
class FedSPDConfig:
    n_clients: int
    n_clusters: int
    tau: int = 5                  # local steps per round
    batch: int = 32
    lr0: float = 5e-2
    lr_decay: float = 0.98        # per-round multiplicative decay
    tau_final: int = 10
    final_lr_scale: float = 0.5
    cluster_chunk: Optional[int] = None
    u_ema: float = 0.3            # "stream" regime u update rate
    regime: str = "full"          # full | stream
    point_to_point: bool = True   # comm accounting mode

    # --- differential privacy (paper B.2.6, following Wei et al. 2020) ---
    # each round's local update delta is L2-clipped to dp_clip and Gaussian
    # noise with std dp_clip * dp_noise_multiplier is added BEFORE the
    # parameter exchange; 0 disables. noise multiplier c = sqrt(2 ln(1.25/δ))/ε.
    dp_clip: float = 0.0
    dp_noise_multiplier: float = 0.0


def init_state(
    key: jax.Array,
    model_init: Callable[[jax.Array], PyTree],
    cfg: FedSPDConfig,
    data_m: int,
) -> FedSPDState:
    """Independent random init per (cluster, client) pair — consensus within
    each cluster emerges from gossip, exactly the DFL setting."""
    k_init, k_state = jax.random.split(key)
    keys = jax.random.split(k_init, cfg.n_clusters * cfg.n_clients)
    keys = keys.reshape(cfg.n_clusters, cfg.n_clients, -1)
    centers = jax.vmap(jax.vmap(model_init))(keys)
    # explicit dtype: a weak-typed u would retrigger jit on the second round
    u = jnp.full((cfg.n_clients, cfg.n_clusters), 1.0 / cfg.n_clusters,
                 jnp.float32)
    z = jnp.zeros((cfg.n_clients, data_m), jnp.int32)
    return FedSPDState(
        centers=centers, u=u, z=z, round=jnp.zeros((), jnp.int32),
        key=k_state, comm_bytes=jnp.zeros((), jnp.float32),
    )


def seeded_init(
    key: jax.Array,
    model_init: Callable[[jax.Array], PyTree],
    cfg: FedSPDConfig,
    loss_fn: Callable,
    data: dict,  # leaves (N, M, ...) — "full" regime layout
    *,
    epochs: int = 15,
    lr: float = 0.1,
    optimizer: Optimizer = None,
) -> FedSPDState:
    """Client-seeded warm start (k-means++-flavoured, no ground truth).

    S distinct randomly-chosen clients each pretrain one cluster center on
    their OWN raw local data, then flood-broadcast it (comm cost: S models,
    once). Because client mixtures differ (U[0.1, 0.9] in the paper's
    construction), the S seeds start genuinely separated — which is what
    Assumption 5.6 (bounded distance to the optimal centers at every step)
    asks of the initialization. Random symmetric inits frequently collapse
    both centers onto one compromise model (EM local optimum); see
    EXPERIMENTS.md §Accuracy for the ablation.
    """
    optimizer = optimizer or sgd()
    state = init_state(key, model_init, cfg, jax.tree.leaves(data)[0].shape[1])
    k_pick, k_run = jax.random.split(jax.random.fold_in(key, 1))
    n = cfg.n_clients
    seeds = jax.random.choice(k_pick, n, (cfg.n_clusters,), replace=False)
    m = jax.tree.leaves(data)[0].shape[1]
    steps = epochs * max(1, m // cfg.batch)
    grad_fn = jax.grad(loss_fn)

    def pretrain_one(s_idx, seed_client):
        # distinct subkeys: reusing fold_in(k_run, s_idx) for BOTH the model
        # init and the batch-sampling scan would correlate the init weights
        # with the batch sequence (same underlying key stream)
        k_model, k_scan = jax.random.split(jax.random.fold_in(k_run, s_idx))
        p = model_init(k_model)
        x_i = jax.tree.map(lambda l: l[seed_client], data)
        batch_all = {"x": x_i["inputs"], "y": x_i["targets"]}
        opt_s = optimizer.init(p)

        def one(carry, k):
            p, opt_s = carry
            idx = jax.random.randint(k, (cfg.batch,), 0, m)
            b = {"x": batch_all["x"][idx], "y": batch_all["y"][idx]}
            p, opt_s = optimizer.update(grad_fn(p, b), opt_s, p, lr)
            return (p, opt_s), None

        (p, _), _ = jax.lax.scan(
            one, (p, opt_s), jax.random.split(k_scan, steps)
        )
        return p

    centers = [pretrain_one(s, seeds[s]) for s in range(cfg.n_clusters)]
    stacked = jax.tree.map(
        lambda *ls: jnp.stack([jnp.broadcast_to(l, (n,) + l.shape) for l in ls]),
        *centers,
    )
    return state._replace(centers=stacked)


def select_clusters(key: jax.Array, u: jnp.ndarray) -> jnp.ndarray:
    """Step 1a: s_i ~ Categorical(u_i)."""
    return jax.random.categorical(key, jnp.log(jnp.maximum(u, 1e-12)), axis=-1)


def _gather_selected(centers: PyTree, s: jnp.ndarray) -> PyTree:
    """centers leaves (S, N, ...) -> selected (N, ...)."""
    n = s.shape[0]
    return jax.tree.map(lambda l: l[s, jnp.arange(n)], centers)


def _scatter_selected(centers: PyTree, s: jnp.ndarray, value: PyTree) -> PyTree:
    n = s.shape[0]
    return jax.tree.map(
        lambda l, v: l.at[s, jnp.arange(n)].set(v.astype(l.dtype)),
        centers, value,
    )


def make_round_step(
    loss_fn: Callable,              # (params, batch) -> scalar
    per_example_loss: Callable,     # (params, batch) -> (B,)
    gossip: GossipSpec,
    cfg: FedSPDConfig,
    optimizer: Optimizer = None,
    lr_schedule: Callable = None,
    mix_fn: Callable = None,        # (c_sel, s) -> mixed; default Eq. (1)
    pack_spec: Optional[PackSpec] = None,  # packed (S, N, X) engine
    model_bytes: Optional[int] = None,     # per-model wire bytes (hoisted)
    donate: bool = False,           # jit + donate the state in place
    comm=None,                      # comm/codecs.CommConfig: wire codec
    sparse=None,                    # core/sparse.SparseConfig: DisPFL masks
):
    """Returns step(state, data, adj=None) -> (state, metrics). ``data``
    leaves: (N, M, ...) in the "full" regime; (N, B, ...) fresh batch in
    "stream".

    ``adj`` is the scenario engine's dynamic-topology hook: THIS ROUND's
    (N, N) adjacency as a TRACED input (time-varying rewire schedules,
    Bernoulli link dropout, per-seed graphs under vmap) instead of the
    gossip spec's closure constant. The weight matrix is rebuilt from it
    each round with row renormalization over the surviving links, and the
    comm accounting charges only active links — a dropped edge costs zero
    wire bytes. Because the adjacency is traced, a whole (rounds, N, N)
    schedule runs through ONE jit compile of the step. ``adj=None`` (the
    default, and every pre-existing call site) keeps the static-graph
    program unchanged. A plain custom ``mix_fn`` only needs to accept
    ``adj=`` when dynamic graphs are actually used; the built-in backends
    (core/gossip.make_mix_fn) all do.

    The traced adjacency may be WEIGHTED (the heterogeneity engine,
    experiments/heterogeneity.py): a zero row+column removes a straggling
    or unavailable client from the round (its mixing row collapses to
    e_i, zero wire bytes charged — the accounting binarizes the matrix),
    and a fractional column decays a stale sender's weight before row
    renormalization. Weighted entries need the dense wiring; the permute
    paths read the adjacency as a binary mask.

    ``comm`` (comm/codecs.CommConfig) runs the exchange through a wire
    codec: the transmitted (N, X) slab is encoded, receivers mix the
    decoded values, and (with ``error_feedback=True``) the per-client
    residual rides ``state.ef`` round over round. Requires the packed
    plane for any codec other than the bit-exact ``fp32`` passthrough.
    When ``mix_fn`` came from ``core/gossip.make_mix_fn(comm=...)`` it is
    comm-aware (fused Pallas dequantize+mix, encoded ppermute payloads);
    a plain ``mix_fn`` is wrapped with the reference decode∘mix∘encode.
    ``state.comm_bytes`` keeps accounting LOGICAL bytes (original
    dtypes); the physical wire bytes are the static per-message codec
    ratio times that — reported by the experiment driver.

    With ``pack_spec`` (core/packing.py), ``state.centers`` must be the
    packed (S, N, X) plane (``packing.pack_state``) and the round runs the
    flat engine; ``mix_fn`` then receives a (N, X) array instead of a
    pytree (every backend in core/gossip.make_mix_fn handles both).
    ``model_bytes`` fixes the per-model wire size for comm accounting once
    at build time (it is static per model); when omitted it is derived
    once at first trace — packed runs always account ORIGINAL dtypes via
    the pack spec, so packing never changes reported comm bytes.

    ``sparse`` (core/sparse.SparseConfig) runs the DisPFL composition:
    ``state.mask`` carries one (N, X) binary mask per client, the local
    step trains on the masked support (masked start + masked gradients),
    the exchange is mask-then-encode with a support-renormalized mix
    (num = W·(M⊙Ĉ), den = W·M; each receiver keeps its own value where
    its mask is dead or no active sender covers the coordinate — the
    effective mixing weights are row-stochastic on the active support),
    and a traced RigL prune/regrow updates the mask in-carry every
    ``update_every`` rounds. ``density >= 1.0`` statically routes back to
    the dense code paths (bit-exact parity), the mask riding along
    unchanged. Requires the packed plane; cosine-alignment filtering does
    not compose (the masked weights are support-, not value-, dependent).

    ``donate=True`` returns the step already jitted with
    ``donate_argnums=0``: XLA aliases the state's buffers input→output
    (the (S, N, X) plane — every round's dominant allocation — is updated
    in place across rounds, no per-round copy). The caller must not reuse
    a state it passed in; drive the loop as ``state, m = step(state, d)``.
    """
    optimizer = optimizer or sgd()
    if lr_schedule is None:
        lr_schedule = lambda t: cfg.lr0 * (cfg.lr_decay ** t)  # noqa: E731
    if mix_fn is None:
        mix_fn = lambda c, sel, adj=None: mix(gossip, c, sel, adj=adj)  # noqa: E731

    channel = None
    if comm is not None and comm.codec != "fp32":
        from repro.comm.codecs import exchange, make_channel

        if pack_spec is None:
            raise ValueError(
                f"comm codec {comm.codec!r} requires the packed parameter "
                "plane (pass pack_spec; fp32 is the only pytree-safe codec)"
            )
        channel = make_channel(comm, pack_spec.size)
        if not getattr(mix_fn, "comm_aware", False):
            # a plain (custom) mix_fn gets the reference composition
            base_mix = mix_fn

            def _wrapped_comm_mix(c_sel, s, key, ef, adj=None):
                inner = ((lambda x: base_mix(x, s)) if adj is None
                         else (lambda x: base_mix(x, s, adj=adj)))
                return exchange(channel, c_sel, inner, key, ef)

            _wrapped_comm_mix.comm_aware = True
            mix_fn = _wrapped_comm_mix

    sparse_on = sparse is not None and sparse.enabled
    if sparse_on:
        if pack_spec is None:
            raise ValueError(
                f"sparse training (density={sparse.density}) requires the "
                "packed parameter plane (pass pack_spec)"
            )
        if gossip.cos_align_threshold > -1.0:
            raise ValueError(
                "sparse training does not compose with cosine-alignment "
                "filtering: the masked mixing weights are support-, not "
                "value-, dependent"
            )

    grad_fn = jax.grad(loss_fn)
    sigma = cfg.dp_clip * cfg.dp_noise_multiplier

    # static per-model wire bytes: computed once here (not per trace in the
    # step bodies); the trace-time fallback fills the cell exactly once
    _model_b = [model_bytes if model_bytes is not None
                else (pack_spec.model_bytes if pack_spec is not None
                      else None)]

    def model_b_of(c_sel):
        if _model_b[0] is None:
            _model_b[0] = tree_bytes(c_sel) // cfg.n_clients
        return _model_b[0]

    def dp_sanitize(c_old, c_new, key):
        """Clip the round's update to cfg.dp_clip and add Gaussian noise
        (Wei et al. 2020) — applied per client before the exchange."""
        if cfg.dp_clip <= 0:
            return c_new

        def one(c_o, c_n, k):
            delta = jax.tree.map(lambda a, b: a.astype(jnp.float32)
                                 - b.astype(jnp.float32), c_n, c_o)
            sq = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(delta))
            scale = jnp.minimum(1.0, cfg.dp_clip / jnp.sqrt(sq + 1e-12))
            leaves, treedef = jax.tree.flatten(delta)
            keys = jax.random.split(k, len(leaves))
            sigma = cfg.dp_clip * cfg.dp_noise_multiplier
            noised = [
                l * scale + sigma * jax.random.normal(kk, l.shape)
                for l, kk in zip(leaves, keys)
            ]
            delta = jax.tree.unflatten(treedef, noised)
            return jax.tree.map(
                lambda b, d: (b.astype(jnp.float32) + d).astype(b.dtype),
                c_o, delta)

        n = jax.tree.leaves(c_new)[0].shape[0]
        return jax.vmap(one)(c_old, c_new, jax.random.split(key, n))

    def dp_flat_parts(c_old, c_new, key):
        """Packed-plane DP: ONE flat L2 norm over (N, X) and one fused
        noise draw — no per-leaf walk, no per-leaf key splits. (The noise
        stream therefore differs from the pytree path's per-leaf draws;
        clip-only parity is exact, noisy parity is statistical.) Clip-only
        rounds (sigma == 0) skip the full-plane draw entirely."""
        delta = c_new - c_old
        sq = jnp.sum(jnp.square(delta), axis=-1, keepdims=True)
        scale = jnp.minimum(1.0, cfg.dp_clip / jnp.sqrt(sq + 1e-12))
        noise = (jax.random.normal(key, c_new.shape, c_new.dtype)
                 if sigma > 0 else None)
        return scale, noise

    def _plain_mix(c_sel, s, adj):
        """Static calls keep the exact pre-scenario call shape (and so the
        exact program); a traced adjacency is only threaded when given —
        custom two-arg mix_fns stay valid for static graphs."""
        return mix_fn(c_sel, s) if adj is None else mix_fn(c_sel, s, adj=adj)

    def _channel_mix(c_sel, s, k_comm, ef, adj):
        """The exchange proper: comm-aware (codec + error feedback)
        threading when a compressing channel is on, the plain mix
        otherwise (identical code path and key stream to before)."""
        if channel is None:
            return _plain_mix(c_sel, s, adj), ef
        if adj is None:
            return mix_fn(c_sel, s, k_comm, ef)
        return mix_fn(c_sel, s, k_comm, ef, adj=adj)

    def exchange_packed(plane, c_old, c_new, s, k_dp, k_comm, ef, adj):
        """Steps (2)+(3) on the flat plane: DP sanitize, wire codec,
        Eq. (1) mix, and the scatter back into (S, N, X) — all
        single-array ops. When the mix backend exposes a fused
        clip·scale+W·C kernel (Pallas), no cosine filtering is on (the
        weight matrix must not depend on the sanitized values), and no
        codec sits between sanitize and mix, the DP round stays a single
        HBM pass. Returns (plane, ef')."""
        if cfg.dp_clip > 0:
            scale, noise = dp_flat_parts(c_old, c_new, k_dp)
            fused = getattr(mix_fn, "fused_dp", None)
            if (channel is None and fused is not None
                    and gossip.cos_align_threshold <= -1.0):
                c_mixed = (fused(c_old, c_new, scale, noise, sigma, s)
                           if adj is None else
                           fused(c_old, c_new, scale, noise, sigma, s,
                                 adj=adj))
            else:
                c_sel = c_old + scale * (c_new - c_old)
                if noise is not None:
                    c_sel = c_sel + sigma * noise
                c_mixed, ef = _channel_mix(c_sel, s, k_comm, ef, adj)
        else:
            c_mixed, ef = _channel_mix(c_new, s, k_comm, ef, adj)
        n = s.shape[0]
        plane = plane.at[s, jnp.arange(n)].set(c_mixed.astype(plane.dtype))
        return plane, ef

    # ---------------- sparse (DisPFL) plane machinery ---------------------

    def exchange_sparse(plane, c_old, c_new, s, smask, k_dp, k_comm, ef, adj):
        """Sparse variant of steps (2)+(3): DP sanitize then RE-mask (noise
        must not densify the support), mask-then-encode on the wire, and a
        support-renormalized mix:

            num = W·(M ⊙ Ĉ)    den = W·M
            out = where(M_i ∧ den > 0, num / den, own value)

        Per coordinate the effective weights w_ij·m_jx/den sum to 1 over
        the senders that carry it — row-stochastic on the active support —
        and a receiver's dead coordinates stay untouched (zero). The EF
        residual is masked after every update so dead coordinates never
        accumulate deferred error."""
        if cfg.dp_clip > 0:
            scale, noise = dp_flat_parts(c_old, c_new, k_dp)
            c_sel = c_old + scale * (c_new - c_old)
            if noise is not None:
                c_sel = c_sel + sigma * noise
            c_sel = smask * c_sel
        else:
            c_sel = c_new  # masked start + masked grads => already on support
        w = fedspd_weight_matrix(gossip, s, None, adj=adj)
        colact = column_activity(smask)
        kernel = getattr(mix_fn, "sparse_matmul", None)

        def matmul(w_, v):
            if kernel is None:
                return jnp.einsum(
                    "ij,jx->ix", w_, v,
                    preferred_element_type=jnp.float32)
            return kernel(w_, v, colact)

        if channel is None:
            num = matmul(w, c_sel)
        else:
            dequant = getattr(mix_fn, "sparse_dequant", None)
            fused = dequant is not None and getattr(channel, "fused", False)
            enc, x_hat, ef = channel.encode_stream(
                c_sel, k_comm, ef, need_hat=channel.has_ef or not fused)
            if ef is not None:
                ef = smask * ef
            if fused:
                num = dequant(w, enc, smask)
            else:
                # decoded zeros stay exactly zero for every codec, but the
                # support contract must not hinge on that: re-mask
                num = matmul(w, smask * x_hat)
        den = matmul(w, smask)
        c_mixed = jnp.where(
            jnp.logical_and(smask > 0, den > 0),
            num / jnp.maximum(den, 1e-12), c_sel,
        )
        plane = plane.at[s, jnp.arange(s.shape[0])].set(
            c_mixed.astype(plane.dtype))
        return plane, ef

    def dense_grads(c_flat, data, z, s, key):
        """One DENSE gradient pass at the post-update masked parameters —
        RigL's regrow score asks where the loss would move dead
        coordinates hardest. Operates on the flat (N, X) slab via the
        pack-spec boundary; skipped statically for regrow="random"."""
        if cfg.regime == "full":
            bx = client_batches(
                key, data["inputs"], data["targets"], z, s, cfg.batch
            )
            batch = {"x": bx[0], "y": bx[1]}

            def one(f, b):
                return loss_fn(unpack(f, pack_spec), b)

            return jax.vmap(jax.grad(one))(c_flat, batch)

        def one(f, b, m):
            pel = per_example_loss(unpack(f, pack_spec), b)
            return jnp.sum(pel * m) / jnp.maximum(jnp.sum(m), 1.0)

        return jax.vmap(jax.grad(one))(c_flat, data["batch"], data["mask"])

    def sparse_mask_update(state, c_new, data, s):
        """Traced RigL prune/regrow riding the round carry. The key is
        derived via fold_in(state.key, round) WITHOUT consuming the main
        split sequence, so loop and scan engines — and the dense program
        when density >= 1 — see identical key streams."""
        k_mask = jax.random.fold_in(
            jax.random.fold_in(state.key, 0x51AB), state.round
        )
        k_grow, k_batch = jax.random.split(k_mask)
        if sparse.regrow == "rigl":
            g_dense = dense_grads(c_new, data, state.z, s, k_batch)
        else:
            g_dense = jnp.zeros_like(c_new)
        return maybe_update_mask(
            state.mask, c_new, g_dense, k_grow, state.round, sparse
        )

    def local_updates(c_sel, data, z, s, key, lr, grad_mask=None):
        """τ SGD steps on the selected centers, cluster-conditional batches.

        ``grad_mask`` (a pytree of {0,1} leaves matching the params) is the
        sparse engine's support projection: gradients are masked every
        step, so a masked start stays on the active support for all τ
        steps — true sparse local training, not mask-at-boundaries."""
        opt_state = jax.vmap(optimizer.init)(c_sel)

        def one_step(carry, k):
            c, opt_s = carry
            if cfg.regime == "full":
                bx = client_batches(
                    k, data["inputs"], data["targets"], z, s, cfg.batch
                )
                batch = {"x": bx[0], "y": bx[1]}
                grads = jax.vmap(grad_fn)(c, batch)
            else:
                # stream: fixed batch, mask examples not in selected cluster
                def masked_loss(params, batch_i, mask_i):
                    pel = per_example_loss(params, batch_i)
                    denom = jnp.maximum(jnp.sum(mask_i), 1.0)
                    return jnp.sum(pel * mask_i) / denom

                grads = jax.vmap(jax.grad(masked_loss))(
                    c, data["batch"], data["mask"]
                )
            if grad_mask is not None:
                grads = jax.tree.map(
                    lambda g, m: g * m.astype(g.dtype), grads, grad_mask
                )
            c, opt_s = jax.vmap(
                lambda g, o, p: optimizer.update(g, o, p, lr)
            )(grads, opt_s, c)
            return (c, opt_s), None

        keys = jax.random.split(key, cfg.tau)
        (c_sel, _), _ = jax.lax.scan(one_step, (c_sel, opt_state), keys)
        return c_sel

    def step_full(state: FedSPDState, data: dict, adj=None):
        key, k_sel, k_local = jax.random.split(state.key, 3)
        lr = lr_schedule(state.round)

        # (1) cluster selection + τ local steps
        s = select_clusters(k_sel, state.u)
        c_sel = _gather_selected(state.centers, s)
        c_new = local_updates(c_sel, data, state.z, s, k_local, lr)
        key, k_dp = jax.random.split(key)
        c_sel = dp_sanitize(c_sel, c_new, k_dp)

        # (2)+(3) exchange & cluster-matched averaging
        c_mixed = _plain_mix(c_sel, s, adj)
        centers = _scatter_selected(state.centers, s, c_mixed)

        # (4) re-cluster all local data and refresh u
        batch_all = {"x": data["inputs"], "y": data["targets"]}
        z, u = cluster_all_clients(
            per_example_loss, centers, batch_all, cfg.n_clusters,
            chunk=cfg.cluster_chunk,
        )

        comm = state.comm_bytes + round_comm_bytes(
            gossip, s, model_b_of(c_sel), point_to_point=cfg.point_to_point,
            adj=adj,
        )
        new_state = FedSPDState(
            centers=centers, u=u, z=z, round=state.round + 1, key=key,
            comm_bytes=comm, mask=state.mask,
        )
        metrics = {
            "lr": lr,
            "selected": s,
            "consensus": _consensus_per_cluster(centers, cfg.n_clusters),
            "comm_bytes": comm,
        }
        return new_state, metrics

    def step_stream(state: FedSPDState, batch: dict, adj=None):
        """batch leaves (N, B, ...): this round's fresh per-client data."""
        key, k_sel, k_local = jax.random.split(state.key, 3)
        lr = lr_schedule(state.round)
        s = select_clusters(k_sel, state.u)
        c_sel = _gather_selected(state.centers, s)

        # per-batch clustering under *current* centers (Step 4, streamed)
        centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), state.centers)

        def assign(centers_i, batch_i):
            losses = jax.vmap(lambda c: per_example_loss(c, batch_i))(centers_i)
            return jnp.argmin(losses, axis=0)  # (B,)

        zb = jax.vmap(assign)(centers_nc, batch)  # (N, B)
        mask = (zb == s[:, None]).astype(jnp.float32)

        c_new = local_updates(
            c_sel, {"batch": batch, "mask": mask}, None, s, k_local, lr
        )
        key, k_dp = jax.random.split(key)
        c_sel = dp_sanitize(c_sel, c_new, k_dp)
        c_mixed = _plain_mix(c_sel, s, adj)
        centers = _scatter_selected(state.centers, s, c_mixed)

        u_batch = jax.vmap(
            lambda z_: mixture_coefficients(z_, cfg.n_clusters)
        )(zb)
        u = (1 - cfg.u_ema) * state.u + cfg.u_ema * u_batch

        comm = state.comm_bytes + round_comm_bytes(
            gossip, s, model_b_of(c_sel), point_to_point=cfg.point_to_point,
            adj=adj,
        )
        new_state = FedSPDState(
            centers=centers, u=u, z=state.z, round=state.round + 1, key=key,
            comm_bytes=comm, mask=state.mask,
        )
        metrics = {
            "lr": lr,
            "selected": s,
            "consensus": _consensus_per_cluster(centers, cfg.n_clusters),
            "comm_bytes": comm,
        }
        return new_state, metrics

    # ---------------- packed (S, N, X) parameter-plane engine -------------

    def step_full_packed(state: FedSPDState, data: dict, adj=None):
        plane = state.centers                       # (S, N, X)
        key, k_sel, k_local = jax.random.split(state.key, 3)
        lr = lr_schedule(state.round)

        # (1) cluster selection + τ local steps. gather = ONE dynamic
        # slice on the plane; the local-SGD scan needs model structure, so
        # parameters take pytree form only inside this scope.
        s = select_clusters(k_sel, state.u)
        c_old = plane[s, jnp.arange(s.shape[0])]    # (N, X)
        if sparse_on:
            # support applies at gather: rows of OTHER clusters may carry
            # coordinates from an older mask; the current mask projects
            c_old = state.mask * c_old
            grad_mask = unpack(state.mask, pack_spec)
        else:
            grad_mask = None
        c_new_tree = local_updates(
            unpack(c_old, pack_spec), data, state.z, s, k_local, lr,
            grad_mask=grad_mask,
        )
        c_new = pack(c_new_tree, pack_spec)
        if channel is None:
            key, k_dp = jax.random.split(key)
            k_comm = None
        else:
            key, k_dp, k_comm = jax.random.split(key, 3)

        # (2)+(3) flat sanitize + wire codec + mix + scatter
        if sparse_on:
            new_mask = sparse_mask_update(state, c_new, data, s)
            plane, ef = exchange_sparse(plane, c_old, c_new, s, state.mask,
                                        k_dp, k_comm, state.ef, adj)
        else:
            new_mask = state.mask
            plane, ef = exchange_packed(plane, c_old, c_new, s, k_dp, k_comm,
                                        state.ef, adj)

        # (4) re-cluster: the forward pass needs model structure again
        batch_all = {"x": data["inputs"], "y": data["targets"]}
        z, u = cluster_all_clients(
            per_example_loss, unpack(plane, pack_spec), batch_all,
            cfg.n_clusters, chunk=cfg.cluster_chunk,
        )

        comm = state.comm_bytes + round_comm_bytes(
            gossip, s, model_b_of(None), point_to_point=cfg.point_to_point,
            adj=adj,
        )
        new_state = FedSPDState(
            centers=plane, u=u, z=z, round=state.round + 1, key=key,
            comm_bytes=comm, ef=ef, mask=new_mask,
        )
        metrics = {
            "lr": lr,
            "selected": s,
            "consensus": _consensus_per_cluster_flat(plane),
            "comm_bytes": comm,
        }
        return new_state, metrics

    def step_stream_packed(state: FedSPDState, batch: dict, adj=None):
        plane = state.centers                       # (S, N, X)
        key, k_sel, k_local = jax.random.split(state.key, 3)
        lr = lr_schedule(state.round)
        s = select_clusters(k_sel, state.u)
        c_old = plane[s, jnp.arange(s.shape[0])]    # (N, X)

        # per-batch clustering under *current* centers (model structure)
        centers_nc = jax.tree.map(
            lambda l: jnp.swapaxes(l, 0, 1), unpack(plane, pack_spec)
        )

        def assign(centers_i, batch_i):
            losses = jax.vmap(lambda c: per_example_loss(c, batch_i))(centers_i)
            return jnp.argmin(losses, axis=0)  # (B,)

        zb = jax.vmap(assign)(centers_nc, batch)  # (N, B)
        mask = (zb == s[:, None]).astype(jnp.float32)

        if sparse_on:
            c_old = state.mask * c_old
            grad_mask = unpack(state.mask, pack_spec)
        else:
            grad_mask = None
        c_new_tree = local_updates(
            unpack(c_old, pack_spec), {"batch": batch, "mask": mask},
            None, s, k_local, lr, grad_mask=grad_mask,
        )
        c_new = pack(c_new_tree, pack_spec)
        if channel is None:
            key, k_dp = jax.random.split(key)
            k_comm = None
        else:
            key, k_dp, k_comm = jax.random.split(key, 3)
        if sparse_on:
            new_mask = sparse_mask_update(
                state, c_new, {"batch": batch, "mask": mask}, s
            )
            plane, ef = exchange_sparse(plane, c_old, c_new, s, state.mask,
                                        k_dp, k_comm, state.ef, adj)
        else:
            new_mask = state.mask
            plane, ef = exchange_packed(plane, c_old, c_new, s, k_dp, k_comm,
                                        state.ef, adj)

        u_batch = jax.vmap(
            lambda z_: mixture_coefficients(z_, cfg.n_clusters)
        )(zb)
        u = (1 - cfg.u_ema) * state.u + cfg.u_ema * u_batch

        comm = state.comm_bytes + round_comm_bytes(
            gossip, s, model_b_of(None), point_to_point=cfg.point_to_point,
            adj=adj,
        )
        new_state = FedSPDState(
            centers=plane, u=u, z=state.z, round=state.round + 1, key=key,
            comm_bytes=comm, ef=ef, mask=new_mask,
        )
        metrics = {
            "lr": lr,
            "selected": s,
            "consensus": _consensus_per_cluster_flat(plane),
            "comm_bytes": comm,
        }
        return new_state, metrics

    if pack_spec is not None:
        step = step_full_packed if cfg.regime == "full" else step_stream_packed
    else:
        step = step_full if cfg.regime == "full" else step_stream
    return jax.jit(step, donate_argnums=0) if donate else step


def _consensus_per_cluster(centers: PyTree, s_clusters: int) -> jnp.ndarray:
    ds = []
    for s_idx in range(s_clusters):
        c_s = jax.tree.map(lambda l: l[s_idx], centers)
        ds.append(consensus_distance(c_s))
    return jnp.stack(ds)


def _consensus_per_cluster_flat(plane: jnp.ndarray) -> jnp.ndarray:
    """Theorem 5.10's E_t per cluster as ONE flat reduction over the packed
    (S, N, X) plane — no per-cluster/per-leaf python loop."""
    p32 = plane.astype(jnp.float32)
    mean = jnp.mean(p32, axis=1, keepdims=True)
    return jnp.sum(jnp.square(p32 - mean), axis=(1, 2)) / plane.shape[1]


# --------------------------------------------------------------------------
# Final phase (Algorithm 1, FINALPHASE)
# --------------------------------------------------------------------------


def personalize(state: FedSPDState,
                pack_spec: Optional[PackSpec] = None) -> PyTree:
    """Eq. (2): x_i = Σ_s u_{i,s} c_{i,s}. Returns leaves (N, ...).

    Packed states collapse to ONE weighted contraction over the plane
    (`(N, S)·(S, N, X) -> (N, X)`), unpacked to pytree form only here —
    the API boundary."""
    if pack_spec is not None:
        plane = state.centers  # (S, N, X)
        mixed = jnp.einsum(
            "ns,snx->nx", state.u.astype(plane.dtype), plane
        )
        return unpack(mixed, pack_spec)
    centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), state.centers)

    def one(centers_i, u_i):
        return tree_weighted_sum(centers_i, u_i)

    return jax.vmap(one)(centers_nc, state.u)


def final_phase(
    state: FedSPDState,
    loss_fn: Callable,
    data: dict,  # leaves (N, M, ...)
    cfg: FedSPDConfig,
    optimizer: Optimizer = None,
    lr: float | None = None,
    pack_spec: Optional[PackSpec] = None,
) -> PyTree:
    """Aggregate (Eq. 2) then τ_final local epochs on ALL local data —
    communication-free personalization. Returns personalized params (N, ...)."""
    optimizer = optimizer or sgd()
    params = personalize(state, pack_spec)
    lr = lr if lr is not None else cfg.lr0 * cfg.final_lr_scale * (
        cfg.lr_decay ** state.round
    )
    grad_fn = jax.grad(loss_fn)
    opt_state = jax.vmap(optimizer.init)(params)

    def one_step(carry, k):
        p, opt_s = carry
        bx, by = client_uniform_batches(k, data["inputs"], data["targets"],
                                        cfg.batch)
        grads = jax.vmap(grad_fn)(p, {"x": bx, "y": by})
        p, opt_s = jax.vmap(lambda g, o, pp: optimizer.update(g, o, pp, lr))(
            grads, opt_s, p
        )
        return (p, opt_s), None

    # tau_final counts EPOCHS over the full local dataset (paper Table 1:
    # "Number of epochs for the final phase"), not SGD steps
    m = jax.tree.leaves(data)[0].shape[1]
    steps = cfg.tau_final * max(1, m // cfg.batch)
    keys = jax.random.split(state.key, steps)
    (params, _), _ = jax.lax.scan(one_step, (params, opt_state), keys)
    return params
