"""DisPFL-style decentralized sparse training on the packed plane.

DisPFL (Dai et al., 2022) personalizes *support*: each client trains a
sparse subnetwork under a fixed parameter budget (``density``), and a
RigL-style update (Evci et al., 2020) periodically drops the
smallest-magnitude active weights and regrows the same number of dead
coordinates where the *dense* gradient is largest. Composed with FedSPD,
every client carries one binary mask over the packed X axis, applied to
whichever cluster model it trains this round.

Everything here is traced and shape-static so the mask stream rides the
round carry unchanged under both engines (Python loop and
``scan_rounds=True``):

- counts are static Python ints derived from (density, prune_rate, X) —
  ``k_active`` ones per client row, always, so density is preserved
  EXACTLY by construction, not in expectation;
- prune keeps the top ``k_active - n_prune`` of ``|w|`` on the active
  support; regrow takes the top ``n_prune`` scores restricted to the
  coordinates inactive BEFORE the update, which makes the regrown support
  disjoint from the pruned support within one update by construction;
- the update is gated with ``jnp.where`` on ``round % update_every`` so
  the scan body stays uniform (1 compile / 1 dispatch), and its
  randomness is key-derived via ``fold_in(key, round)`` so loop and scan
  engines see the identical mask stream.

``density >= 1.0`` disables the subsystem statically (the
``make_channel -> None`` idiom): callers fall back to the dense code
paths, which is what makes density=1.0 parity bit-exact.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_REGROW_MODES = ("rigl", "random")


@dataclasses.dataclass(frozen=True)
class SparseConfig:
    """Static sparse-training policy (hashable: jit-cache key material).

    density       fraction of the packed X axis each client keeps active,
                  in (0, 1]; 1.0 means dense (subsystem off).
    prune_rate    fraction of the ACTIVE set pruned (and regrown) per
                  mask update, in [0, 1).
    regrow        "rigl" regrows where |dense grad| is largest;
                  "random" regrows uniformly at random.
    update_every  rounds between mask updates (the mask is frozen in
                  between, as in DisPFL's infrequent-adjustment regime).
    """

    density: float = 1.0
    prune_rate: float = 0.2
    regrow: str = "rigl"
    update_every: int = 10

    def __post_init__(self):
        if not 0.0 < float(self.density) <= 1.0:
            raise ValueError(
                f"density must be in (0, 1], got {self.density}")
        if not 0.0 <= float(self.prune_rate) < 1.0:
            raise ValueError(
                f"prune_rate must be in [0, 1), got {self.prune_rate}")
        if self.regrow not in _REGROW_MODES:
            raise ValueError(
                f"regrow must be one of {_REGROW_MODES}, got "
                f"{self.regrow!r}")
        if int(self.update_every) < 1:
            raise ValueError(
                f"update_every must be >= 1, got {self.update_every}")

    @property
    def enabled(self) -> bool:
        """Static on/off switch — density 1.0 routes callers to the dense
        code paths so dense-vs-sparse parity is bit-exact, not approximate."""
        return float(self.density) < 1.0

    def k_active(self, x: int) -> int:
        """Active coordinates per client row (static)."""
        return min(x, max(1, int(round(float(self.density) * x))))

    def n_prune(self, x: int) -> int:
        """Coordinates pruned (= regrown) per update (static). Capped by
        the dead-coordinate count: regrow draws only from coordinates
        inactive before the update."""
        k = self.k_active(x)
        return min(int(float(self.prune_rate) * k), x - k)


def init_masks(key, n: int, x: int, cfg: SparseConfig) -> jnp.ndarray:
    """(n, x) float32 {0,1} masks with EXACTLY ``k_active`` ones per row
    (top-k of i.i.d. uniform scores — exact counts, no tie hazard)."""
    k = cfg.k_active(x)
    if k >= x:
        return jnp.ones((n, x), jnp.float32)
    scores = jax.random.uniform(key, (n, x))
    _, idx = jax.lax.top_k(scores, k)
    rows = jnp.arange(n)[:, None]
    return jnp.zeros((n, x), jnp.float32).at[rows, idx].set(1.0)


def rigl_update(mask: jnp.ndarray, weights: jnp.ndarray,
                grads: jnp.ndarray, key, cfg: SparseConfig) -> jnp.ndarray:
    """One unconditional RigL prune/regrow pass over (n, x) rows.

    Keeps the ``k_active - n_prune`` largest-|w| active coordinates, then
    regrows ``n_prune`` coordinates chosen from the pre-update INACTIVE
    set (top |dense grad| for "rigl", uniform scores for "random"). The
    kept and regrown supports are disjoint by construction, so the result
    has exactly ``k_active`` ones per row — density is invariant."""
    n, x = mask.shape
    n_prune = cfg.n_prune(x)
    if n_prune == 0:
        return mask
    n_keep = cfg.k_active(x) - n_prune
    neg = jnp.asarray(-jnp.inf, jnp.float32)
    active = mask > 0
    rows = jnp.arange(n)[:, None]

    keep_scores = jnp.where(active, jnp.abs(weights.astype(jnp.float32)), neg)
    _, keep_idx = jax.lax.top_k(keep_scores, n_keep)
    kept = jnp.zeros((n, x), jnp.float32).at[rows, keep_idx].set(1.0)

    if cfg.regrow == "rigl":
        grow_scores = jnp.abs(grads.astype(jnp.float32))
    else:
        grow_scores = jax.random.uniform(key, (n, x))
    grow_scores = jnp.where(active, neg, grow_scores)
    _, grow_idx = jax.lax.top_k(grow_scores, n_prune)
    grown = jnp.zeros((n, x), jnp.float32).at[rows, grow_idx].set(1.0)
    return kept + grown


def maybe_update_mask(mask, weights, grads, key, rnd,
                      cfg: SparseConfig) -> jnp.ndarray:
    """``jnp.where``-gated RigL step: the scan body stays uniform, and the
    mask changes only when ``rnd % update_every == 0`` (and never at round
    0 — the init masks hold for the first window)."""
    new = rigl_update(mask, weights, grads, key, cfg)
    fire = jnp.logical_and(rnd % cfg.update_every == 0, rnd > 0)
    return jnp.where(fire, new, mask)


def column_activity(mask: jnp.ndarray) -> jnp.ndarray:
    """(..., n, x) masks -> (..., x) float {0,1}: a packed column is live
    iff ANY client keeps it. This is the skip granularity of the sparse
    Pallas mix — a 128-aligned block whose every column is dead for every
    client is skipped whole in the W·C pass."""
    return (jnp.sum(mask, axis=-2) > 0).astype(jnp.float32)
