# FedSPD — the paper's primary contribution: soft-clustered personalized
# decentralized FL (round step, cluster-matched gossip, data clustering,
# final personalization phase).
from repro.core.clustering import (  # noqa: F401
    assign_clusters,
    cluster_all_clients,
    clustering_accuracy,
    mixture_coefficients,
)
from repro.core.fedspd import (  # noqa: F401
    FedSPDConfig,
    FedSPDState,
    final_phase,
    init_state,
    make_round_step,
    personalize,
    seeded_init,
    select_clusters,
)
from repro.core.gossip import (  # noqa: F401
    MIX_BACKENDS,
    GossipSpec,
    consensus_distance,
    fedspd_weight_matrix,
    make_mix_fn,
    mix,
    mix_dense,
    mix_permute,
    round_comm_bytes,
)
from repro.core.packing import (  # noqa: F401
    PackSpec,
    make_pack_spec,
    pack,
    pack_state,
    unpack,
    unpack_state,
)
