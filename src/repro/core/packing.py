"""Packed parameter plane: the whole center stack as ONE flat array.

FedSPD's matrix notation treats the cluster-s center stack as C_s in
R^{N x X}; the code historically realized it as a pytree with leaves
(S, N, *model_dims) and walked the tree leaf-by-leaf in every hot-path
stage (gossip mix, DP sanitize, cosine alignment, consensus, Eq. (2)).
That turns what should be one streaming HBM pass into L passes with
ragged tails, and the Pallas gossip backend into L ``pallas_call``
launches per round.

``PackSpec`` computes the unravel metadata ONCE — per-leaf offsets,
shapes, dtypes, and the total flat width X are static Python values fixed
at trace time — so the round step can run end-to-end on a single
``(S, N, X)`` buffer:

    plane = pack(centers_tree, spec)     # (S, N, X) fp32
    tree  = unpack(plane, spec)          # leaves (S, N, ...) orig dtypes

``pack``/``unpack`` are shape-polymorphic in the leading batch dims (the
same spec serves (X,), (N, X), (S, N, X), and a vmapped (K, S, N, X)) and
jit/vmap-safe: all slicing uses static offsets. The plane dtype defaults
to fp32 — the master-precision accumulate dtype of every hot-path stage —
and ``unpack`` casts back to each leaf's original dtype, so pack∘unpack
is exact for fp32/bf16/fp16 leaves. Models only enter/leave pytree form
at the API boundary (init, eval, checkpoint); everything between is flat.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static unravel metadata for one model pytree (computed once)."""

    treedef: Any
    shapes: tuple  # per-leaf model-dim shapes, e.g. ((128, 64), (64,), ...)
    dtypes: tuple  # per-leaf original dtypes
    sizes: tuple   # per-leaf flat sizes (prod of shape)
    offsets: tuple  # per-leaf start offset into the X axis
    size: int       # X: total flat width
    dtype: Any = jnp.float32  # plane dtype (master precision)

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    @property
    def model_bytes(self) -> int:
        """Per-model bytes in the ORIGINAL dtypes — what actually crosses
        the wire (comm accounting must not change when the compute
        representation does)."""
        return int(sum(s * np.dtype(d).itemsize
                       for s, d in zip(self.sizes, self.dtypes)))

    @property
    def digest(self) -> str:
        """Content hash of the layout (shapes/dtypes/sizes/offsets/width).
        A servable artifact records this so a server can refuse to unpack
        a plane through a spec built from a different architecture, rather
        than silently reshaping X into the wrong leaves. The treedef is
        covered indirectly: same arch ⇒ same flatten order."""
        parts = [
            ";".join(f"{s}:{np.dtype(d).name}"
                     for s, d in zip(self.shapes, self.dtypes)),
            ",".join(map(str, self.sizes)),
            ",".join(map(str, self.offsets)),
            str(self.size),
        ]
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]


def make_pack_spec(example: PyTree, dtype=jnp.float32) -> PackSpec:
    """Build the static packing metadata from ONE model's pytree (arrays or
    ``jax.ShapeDtypeStruct``s — use ``jax.eval_shape(model_init, key)`` to
    avoid materializing weights)."""
    leaves, treedef = jax.tree.flatten(example)
    shapes = tuple(tuple(leaf.shape) for leaf in leaves)
    dtypes = tuple(jnp.dtype(leaf.dtype) for leaf in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return PackSpec(
        treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
        offsets=offsets, size=int(sum(sizes)), dtype=jnp.dtype(dtype),
    )


def _batch_ndim(leaf_ndim: int, shape: tuple) -> int:
    bnd = leaf_ndim - len(shape)
    if bnd < 0:
        raise ValueError(
            f"leaf rank {leaf_ndim} smaller than packed model rank "
            f"{len(shape)} — tree does not match the pack spec"
        )
    return bnd


def pack(tree: PyTree, spec: PackSpec) -> jnp.ndarray:
    """Leaves (*B, *model_dims) -> one (*B, X) plane (any batch prefix B,
    shared by all leaves: (), (N,), (S, N), a vmapped (K, S, N), ...)."""
    leaves, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} != spec {spec.treedef}")
    bnd = _batch_ndim(leaves[0].ndim, spec.shapes[0])
    flat = []
    for leaf, shape, size in zip(leaves, spec.shapes, spec.sizes):
        if _batch_ndim(leaf.ndim, shape) != bnd or tuple(leaf.shape[bnd:]) != shape:
            raise ValueError(
                f"leaf shape {leaf.shape} does not end with packed shape "
                f"{shape} (batch ndim {bnd})"
            )
        flat.append(jnp.reshape(leaf, leaf.shape[:bnd] + (size,))
                    .astype(spec.dtype))
    return jnp.concatenate(flat, axis=-1)


def unpack(plane: jnp.ndarray, spec: PackSpec) -> PyTree:
    """(*B, X) plane -> pytree with leaves (*B, *model_dims), cast back to
    each leaf's original dtype. Offsets are static, so this lowers to
    static slices (free under XLA fusion)."""
    if plane.shape[-1] != spec.size:
        raise ValueError(f"plane width {plane.shape[-1]} != spec X {spec.size}")
    batch = plane.shape[:-1]
    leaves = [
        jnp.reshape(plane[..., o:o + sz], batch + shape).astype(dt)
        for o, sz, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)


def maybe_unpack(x, spec: Optional[PackSpec]):
    """The pytree re-entry boundary shared by every method's
    personalize/eval: unpack when running packed, identity otherwise —
    one place to change if the boundary ever grows semantics (dtype
    restoration, donation-safe copies, ...)."""
    return unpack(x, spec) if spec is not None else x


def flat_apply(fn, spec: PackSpec):
    """Lift ``fn(params_pytree, *args)`` to ``fn(flat_vec, *args)``.

    The flat (*B, X) parameter vector is unpacked ONLY at the forward
    boundary — the static slices lower to views that XLA fuses into the
    forward, so no materialized copy of the parameters exists outside the
    plane. Everything upstream of the call (SGD updates, gossip averages,
    proximal pulls) stays single-array arithmetic on the plane."""
    def wrapped(vec, *args, **kwargs):
        return fn(unpack(vec, spec), *args, **kwargs)

    return wrapped


def flat_grad(loss_fn, spec: PackSpec):
    """d loss / d flat-vector, as ``pack(grad(loss_fn)(unpack(vec)))``.

    ``unpack`` is an index-preserving reshape (every vec element maps to
    exactly one leaf element), so the packed pytree gradient IS the flat
    gradient. Computing it this way — rather than ``jax.grad`` straight
    through the unpack boundary — matters: the transpose of each static
    slice is a full-width zero-pad, so grad-through-unpack materializes L
    padded (*B, X) cotangents and add_n's them (L× the plane's traffic per
    step, measured ~2× slower on CPU); this form keeps the backward
    leaf-local and pays ONE concat. The result feeds fused single-array
    SGD: ``vec - lr * flat_grad(...)`` with no per-leaf walk."""
    g = jax.grad(loss_fn)

    def grad_vec(vec, *args, **kwargs):
        return pack(g(unpack(vec, spec), *args, **kwargs), spec)

    return grad_vec


def flat_add_grads(vec: jnp.ndarray, grad_tree: PyTree, scale,
                   spec: PackSpec) -> jnp.ndarray:
    """``vec[..., o_l:o_l+sz_l] += scale * grad_l`` for every leaf: the
    plane-side SGD update with NO flat-grad concat.

    Each static-slice ``.at[].add`` lowers to an in-place fused update on
    the (donated) plane, so a τ-step round writes the plane's X axis
    exactly once per step — materializing ``pack(grads)`` first would cost
    a second full-width copy per step (measured ~15% slower on CPU), and
    ``jax.grad`` through the unpack boundary is worse still (the slice
    transpose is a full-width zero-pad per leaf). ``scale`` is typically
    ``-lr``; addition of the scaled gradient is bit-identical to the
    per-leaf ``p - lr·g`` (IEEE ``a + (-b) == a - b``)."""
    leaves, treedef = jax.tree.flatten(grad_tree)
    if treedef != spec.treedef:
        raise ValueError(f"grad structure {treedef} != spec {spec.treedef}")
    for o, sz, shape, leaf in zip(spec.offsets, spec.sizes, spec.shapes,
                                  leaves):
        bnd = _batch_ndim(leaf.ndim, shape)
        g = jnp.reshape(leaf, leaf.shape[:bnd] + (sz,)).astype(spec.dtype)
        vec = vec.at[..., o:o + sz].add(scale * g)
    return vec


def plane_losses(spec, loss_fn=None, per_example_loss=None):
    """Flat-parameter views of a model's loss functions (the apply/grad
    bridge used by every baseline's packed step). With ``spec=None`` this
    is the identity — call sites stay representation-agnostic."""
    if spec is None:
        return loss_fn, per_example_loss
    return (
        flat_apply(loss_fn, spec) if loss_fn is not None else None,
        flat_apply(per_example_loss, spec) if per_example_loss is not None
        else None,
    )


def pack_state(state, spec: PackSpec):
    """FedSPDState with pytree centers -> same state with the (S, N, X)
    plane as ``centers`` (an array is a valid pytree, so the NamedTuple —
    and everything downstream that treats centers opaquely — is unchanged)."""
    return state._replace(centers=pack(state.centers, spec))


def unpack_state(state, spec: PackSpec):
    """Inverse of ``pack_state`` (checkpoint / eval boundary)."""
    return state._replace(centers=unpack(state.centers, spec))
