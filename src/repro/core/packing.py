"""Packed parameter plane: the whole center stack as ONE flat array.

FedSPD's matrix notation treats the cluster-s center stack as C_s in
R^{N x X}; the code historically realized it as a pytree with leaves
(S, N, *model_dims) and walked the tree leaf-by-leaf in every hot-path
stage (gossip mix, DP sanitize, cosine alignment, consensus, Eq. (2)).
That turns what should be one streaming HBM pass into L passes with
ragged tails, and the Pallas gossip backend into L ``pallas_call``
launches per round.

``PackSpec`` computes the unravel metadata ONCE — per-leaf offsets,
shapes, dtypes, and the total flat width X are static Python values fixed
at trace time — so the round step can run end-to-end on a single
``(S, N, X)`` buffer:

    plane = pack(centers_tree, spec)     # (S, N, X) fp32
    tree  = unpack(plane, spec)          # leaves (S, N, ...) orig dtypes

``pack``/``unpack`` are shape-polymorphic in the leading batch dims (the
same spec serves (X,), (N, X), (S, N, X), and a vmapped (K, S, N, X)) and
jit/vmap-safe: all slicing uses static offsets. The plane dtype defaults
to fp32 — the master-precision accumulate dtype of every hot-path stage —
and ``unpack`` casts back to each leaf's original dtype, so pack∘unpack
is exact for fp32/bf16/fp16 leaves. Models only enter/leave pytree form
at the API boundary (init, eval, checkpoint); everything between is flat.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static unravel metadata for one model pytree (computed once)."""

    treedef: Any
    shapes: tuple  # per-leaf model-dim shapes, e.g. ((128, 64), (64,), ...)
    dtypes: tuple  # per-leaf original dtypes
    sizes: tuple   # per-leaf flat sizes (prod of shape)
    offsets: tuple  # per-leaf start offset into the X axis
    size: int       # X: total flat width
    dtype: Any = jnp.float32  # plane dtype (master precision)

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    @property
    def model_bytes(self) -> int:
        """Per-model bytes in the ORIGINAL dtypes — what actually crosses
        the wire (comm accounting must not change when the compute
        representation does)."""
        return int(sum(s * np.dtype(d).itemsize
                       for s, d in zip(self.sizes, self.dtypes)))


def make_pack_spec(example: PyTree, dtype=jnp.float32) -> PackSpec:
    """Build the static packing metadata from ONE model's pytree (arrays or
    ``jax.ShapeDtypeStruct``s — use ``jax.eval_shape(model_init, key)`` to
    avoid materializing weights)."""
    leaves, treedef = jax.tree.flatten(example)
    shapes = tuple(tuple(leaf.shape) for leaf in leaves)
    dtypes = tuple(jnp.dtype(leaf.dtype) for leaf in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return PackSpec(
        treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
        offsets=offsets, size=int(sum(sizes)), dtype=jnp.dtype(dtype),
    )


def _batch_ndim(leaf_ndim: int, shape: tuple) -> int:
    bnd = leaf_ndim - len(shape)
    if bnd < 0:
        raise ValueError(
            f"leaf rank {leaf_ndim} smaller than packed model rank "
            f"{len(shape)} — tree does not match the pack spec"
        )
    return bnd


def pack(tree: PyTree, spec: PackSpec) -> jnp.ndarray:
    """Leaves (*B, *model_dims) -> one (*B, X) plane (any batch prefix B,
    shared by all leaves: (), (N,), (S, N), a vmapped (K, S, N), ...)."""
    leaves, treedef = jax.tree.flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree structure {treedef} != spec {spec.treedef}")
    bnd = _batch_ndim(leaves[0].ndim, spec.shapes[0])
    flat = []
    for leaf, shape, size in zip(leaves, spec.shapes, spec.sizes):
        if _batch_ndim(leaf.ndim, shape) != bnd or tuple(leaf.shape[bnd:]) != shape:
            raise ValueError(
                f"leaf shape {leaf.shape} does not end with packed shape "
                f"{shape} (batch ndim {bnd})"
            )
        flat.append(jnp.reshape(leaf, leaf.shape[:bnd] + (size,))
                    .astype(spec.dtype))
    return jnp.concatenate(flat, axis=-1)


def unpack(plane: jnp.ndarray, spec: PackSpec) -> PyTree:
    """(*B, X) plane -> pytree with leaves (*B, *model_dims), cast back to
    each leaf's original dtype. Offsets are static, so this lowers to
    static slices (free under XLA fusion)."""
    if plane.shape[-1] != spec.size:
        raise ValueError(f"plane width {plane.shape[-1]} != spec X {spec.size}")
    batch = plane.shape[:-1]
    leaves = [
        jnp.reshape(plane[..., o:o + sz], batch + shape).astype(dt)
        for o, sz, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)


def pack_state(state, spec: PackSpec):
    """FedSPDState with pytree centers -> same state with the (S, N, X)
    plane as ``centers`` (an array is a valid pytree, so the NamedTuple —
    and everything downstream that treats centers opaquely — is unchanged)."""
    return state._replace(centers=pack(state.centers, spec))


def unpack_state(state, spec: PackSpec):
    """Inverse of ``pack_state`` (checkpoint / eval boundary)."""
    return state._replace(centers=unpack(state.centers, spec))
