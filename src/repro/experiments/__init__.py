# Experiment layer: method registry + shared driver + scenario engine.
# Algorithms register a Method adapter (registry.py); the driver (runner.py)
# owns the round loop, eval cadence, curve/comm accounting, and multi-seed
# batching; scenarios.py declares dynamic topologies / link dropout /
# stacked per-seed data; heterogeneity.py declares per-client system
# models (stragglers, availability, stale gossip).
from repro.comm.codecs import CommConfig  # noqa: F401  (RunConfig(comm=...))
from repro.experiments.config import RunConfig  # noqa: F401
from repro.experiments.export import (  # noqa: F401
    cluster_plane,
    export_run,
    export_servable,
)
from repro.experiments.heterogeneity import (  # noqa: F401
    ClientSystemModel,
    HetCarry,
)
from repro.experiments.registry import (  # noqa: F401
    CommModel,
    ExperimentContext,
    Method,
    available_methods,
    build_context,
    get_method,
    register,
)
from repro.experiments.runner import (  # noqa: F401
    METHODS,
    RunResult,
    run_method,
    run_method_batch,
)
from repro.experiments.scenarios import Scenario  # noqa: F401
from repro.telemetry import TelemetryConfig  # noqa: F401  (RunConfig(telemetry=...))
