"""RunConfig: the one configuration object both experiment entry points take.

Before this module, ``run_method`` and ``run_method_batch`` each carried
seven parallel convenience kwargs (gossip_mode / gossip_backend /
param_plane / comm / scenario / eval_every / options) whose merge logic was
duplicated across the two drivers.  ``RunConfig`` replaces all of them:

    run_method("fedspd", data, exp, cfg=RunConfig(param_plane=True,
                                                  comm=CommConfig("int8"),
                                                  scan_rounds=True))

The old loose kwargs survive as shims that emit ``DeprecationWarning``
(experiments/runner.py); new callers inside this repo must use ``cfg=``
(enforced by tests/test_run_config.py's call-site guard).

``resolve_options`` folds the typed fields into the per-run ``options``
dict the method registry consumes — explicit ``options`` entries win, the
typed fields are shorthand, exactly like the old ``_merge_options``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


def _normalize_comm(options: dict) -> None:
    """A compressing codec operates on packed plane slices, so ``comm``
    implies ``param_plane=True`` — enabled here unless the caller
    explicitly pinned the pytree engine (then fail loudly: silently
    flipping the representation would misattribute benchmark results)."""
    comm = options.get("comm")
    if comm is None or comm.codec == "fp32":
        return
    if options.get("param_plane") is False:
        raise ValueError(
            f"comm codec {comm.codec!r} requires the packed parameter "
            "plane, but param_plane=False was requested — drop one of the "
            "two (fp32 is the only pytree-safe codec)"
        )
    options.setdefault("param_plane", True)


def _normalize_sparse(options: dict) -> None:
    """Sparse masks live on the packed X axis, so an ENABLED
    ``SparseConfig`` (density < 1) implies ``param_plane=True`` — same
    contract (and same loud failure) as a compressing codec."""
    sparse = options.get("sparse")
    if sparse is None or not sparse.enabled:
        return
    if options.get("param_plane") is False:
        raise ValueError(
            f"sparse training (density={sparse.density}) requires the "
            "packed parameter plane, but param_plane=False was requested "
            "— drop one of the two"
        )
    options.setdefault("param_plane", True)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything about HOW a run executes (the what — method, data, exp,
    graph, seeds — stays positional on the entry points).

    gossip_mode     FedSPD wiring: "dense" | "permute"
    gossip_backend  exchange execution: "reference" | "pallas" | "ppermute"
    param_plane     packed (S, N, X) parameter plane vs per-leaf pytrees
    comm            comm/codecs.CommConfig wire codec (implies param_plane
                    for compressing codecs)
    scenario        experiments/scenarios.Scenario: dynamic topologies,
                    in-step link dropout, stacked per-seed data, and
                    client-system heterogeneity (``Scenario.system`` — an
                    experiments/heterogeneity.ClientSystemModel: straggler
                    timeouts, Bernoulli/Markov availability, stale-gossip
                    decay; inactive clients drop like failed links, zero
                    wire bytes, state rows carried bit-untouched)
    eval_every      train-curve cadence (the final round always evaluates)
    donate          donate the state into the jitted round program (the
                    plane is aliased in place; disable when holding on to
                    intermediate states)
    scan_rounds     fold ALL ``exp.rounds`` rounds into one lax.scan-rolled
                    jitted program: one compile, one dispatch, the curve
                    comes back as masked scan ys (see README
                    "Scan-rolled rounds")
    cohort_size     per-round client subsampling: K <= N active clients are
                    gathered into a compact plane each round; inactive
                    clients' rows are carried untouched and cost zero wire
                    bytes (FedSPD on the packed plane, dense wiring)
    sparse          core/sparse.SparseConfig: DisPFL-style per-client
                    binary masks over the packed X axis with a traced RigL
                    prune/regrow update riding the round carry (implies
                    param_plane when density < 1; see README "Sparse
                    training")
    telemetry       telemetry.TelemetryConfig: collect per-round traced
                    metric streams (bytes, cluster-weight entropy/drift,
                    consensus residual, effective degree, spectral gap,
                    staleness) INSIDE the round program — zero extra
                    dispatches, bit-identical between engines; the payload
                    lands on ``RunResult.telemetry`` (see README
                    "Observability")
    options         escape hatch for per-method knobs (explicit entries win
                    over the typed shorthands above)
    """

    gossip_mode: Optional[str] = None
    gossip_backend: Optional[str] = None
    param_plane: Optional[bool] = None
    comm: Any = None                  # comm/codecs.CommConfig
    scenario: Any = None              # experiments/scenarios.Scenario
    eval_every: int = 10
    donate: bool = True
    scan_rounds: bool = False
    cohort_size: Optional[int] = None
    sparse: Any = None                # core/sparse.SparseConfig
    telemetry: Any = None             # telemetry.TelemetryConfig
    options: dict = dataclasses.field(default_factory=dict)

    def resolve_options(self) -> dict:
        """Fold the typed fields into a fresh per-run options dict
        (explicit ``options`` entries win — the fields are shorthand)."""
        options = dict(self.options or {})
        if self.gossip_mode is not None:
            options.setdefault("mode", self.gossip_mode)
        if self.gossip_backend is not None:
            options.setdefault("gossip_backend", self.gossip_backend)
        if self.param_plane is not None:
            options.setdefault("param_plane", self.param_plane)
        if self.comm is not None:
            options.setdefault("comm", self.comm)
        if self.sparse is not None:
            options.setdefault("sparse", self.sparse)
        if not self.donate:
            options.setdefault("donate", False)
        _normalize_comm(options)
        _normalize_sparse(options)
        return options
