"""Export: a finished FedSPD run -> a servable cluster-plane artifact.

The run owns N·S cluster-center copies (consensus makes the N copies of
each cluster agree); the server needs the S consensus models as one
(S, X) plane plus the trained (N, S) mixture table. ``cluster_plane``
lifts the first from a final method state (packed plane OR pytree
engine), ``export_servable`` ships it in a serve/artifact.py format, and
``export_run`` does both straight from a RunResult produced with
``RunConfig(options={"keep_state": True})`` (experiments/runner.py stashes
the final state + PackSpec in ``extras``).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.checkpoint.ckpt import CkptManifest
from repro.core.packing import PackSpec, make_pack_spec, pack
from repro.serve.artifact import save_servable


def cluster_plane(state, spec: Optional[PackSpec] = None) -> jnp.ndarray:
    """(S, X) consensus cluster plane from a final FedSPD state: the mean
    over the client axis of each cluster's N center copies (the consensus
    estimate — after convergence the copies agree and the mean is any of
    them). Accepts both engines: a packed (S, N, X) ``centers`` plane, or
    the pytree engine's (S, N, ...) leaves packed through ``spec``."""
    centers = state.centers
    if isinstance(centers, jnp.ndarray) and centers.ndim == 3:
        plane_snx = centers
    else:
        if spec is None:
            raise ValueError(
                "pytree-engine state needs spec= to pack the centers")
        plane_snx = pack(centers, spec)           # (S, N, X)
    return plane_snx.mean(axis=1)


def export_servable(state, spec: PackSpec, path: str, *, arch: str,
                    codec: str = "fp32", qblock: int = 64,
                    key=None) -> CkptManifest:
    """Ship a final FedSPD state as a servable artifact: consensus plane
    in ``codec`` form + the trained (N, S) mixture table."""
    plane = cluster_plane(state, spec)
    return save_servable(path, plane, spec, arch=arch, u=state.u,
                         codec=codec, qblock=qblock, key=key)


def export_run(result, path: str, *, arch: str = "mlp",
               codec: str = "fp32", qblock: int = 64,
               key=None) -> CkptManifest:
    """Export straight from a RunResult. The run must have been driven
    with ``RunConfig(options={"keep_state": True})`` so the final state
    (and its PackSpec, when the packed engine ran) is in ``extras``."""
    if "state" not in result.extras:
        raise ValueError(
            "RunResult has no final state; run with "
            'RunConfig(options={"keep_state": True}) to export'
        )
    state = result.extras["state"]
    spec = result.extras.get("pack_spec")
    if spec is None:
        # pytree engine: derive the layout from the centers' leaves
        # (strip the (S, N) prefix from the first cluster/client copy)
        import jax

        one = jax.tree.map(lambda l: l[0, 0], state.centers)
        spec = make_pack_spec(one)
    return export_servable(state, spec, path, arch=arch, codec=codec,
                           qblock=qblock, key=key)
