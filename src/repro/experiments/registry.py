"""Method registry: every FL algorithm behind one pluggable contract.

The experiment driver (experiments/runner.py) knows nothing about
individual algorithms.  Each method — FedSPD and the paper's six baselines,
decentralized (``dfl_``) and centralized (``cfl_``) variants — registers a
``Method`` adapter here and the driver owns the round loop, eval cadence,
curve collection, communication accounting, and multi-seed batching.

The ``Method`` protocol (all functions pure & traceable so the driver can
``jax.jit`` the step once and ``jax.vmap`` it over a seed axis):

    init(ctx, key, train=None) -> state       per-seed state (params/pytrees)
    make_step(ctx)        -> step(state, train, key, lr[, adj]) -> (state, aux)
                                              (adj: traced per-round (N, N)
                                              adjacency — methods with
                                              supports_dynamic_graph)
    personalize(ctx, state, key, train=None) -> params   leaves (N, ...)
    comm_model(ctx)       -> CommModel        static per-round bytes or
                                              "tracked" (read from state)
    evaluate(ctx, state, key, on, train=None) -> (N,)    per-client accuracy
                                              (defaults to personalize +
                                              acc_fn; train overrides
                                              ctx.train for the stacked-
                                              data seed axis)
    extras(ctx, state, aux) -> dict           host-side diagnostics

Per-run ``options`` honoured across methods:
    param_plane     run the method's step on the packed parameter plane
                    (core/packing.py: (N, X) per-client models, (S, N, X)
                    center stacks) instead of per-leaf pytree walks.
                    Supported by ALL built-in method ids and parity-tested
                    against the pytree reference; the driver raises
                    ValueError for adapters that have not opted in.
    gossip_backend  execution path for the exchange: "reference" | "pallas"
                    (+ "ppermute" for FedSPD — core/gossip.make_mix_fn's
                    shard_map edge-colored collective schedule, one device
                    per client). Baselines route their static-matrix
                    average through kernels/gossip_mix on "pallas".
    comm            comm/codecs.CommConfig: the wire codec for every
                    exchange ("fp32" passthrough | "int8"/"int4"
                    stochastic per-block quantization | "topk"
                    sparsification, plus error_feedback). Compressing
                    codecs run on the packed plane (the driver enables
                    param_plane automatically); RunResult reports both
                    logical and physical wire bytes.
FedSPD additionally honours:
    mode            gossip wiring: "dense" | "permute"
    dp_clip, dp_noise_multiplier, tau_final, cos_align_threshold

Dynamic topologies (experiments/scenarios.py) ride the step's optional
``adj`` argument — see ``Method.supports_dynamic_graph`` below.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.baselines import fedavg, fedem, fedsoft, ifca, local, pfedme
from repro.baselines.common import mixing_matrix, per_client_eval
from repro.comm.codecs import join_ef, make_channel
from repro.configs.paper_cnn import PaperExpConfig
from repro.core import (
    FedSPDConfig,
    GossipSpec,
    final_phase,
    make_round_step,
    seeded_init,
)
from repro.core.gossip import make_mix_fn
from repro.core.packing import make_pack_spec, pack, pack_state, unpack
from repro.core.sparse import init_masks
from repro.graphs.topology import Graph, complete
from repro.models.smallnets import make_classifier
from repro.utils.pytree import tree_bytes, tree_weighted_sum

PyTree = Any


# --------------------------------------------------------------------------
# Context shared by every adapter
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExperimentContext:
    """Everything a Method needs to build its state and step function."""

    exp: PaperExpConfig
    graph: Graph
    n_clients: int
    n_clusters: int
    model_init: Callable[[jax.Array], PyTree]
    apply_fn: Callable
    loss_fn: Callable
    pel_fn: Callable        # per-example loss (clustering / EM steps)
    acc_fn: Callable
    model_bytes: int
    train: dict             # {"inputs": (N, M, d), "targets": (N, M)}
    test: dict
    options: dict = dataclasses.field(default_factory=dict)

    def opt(self, name: str, default=None):
        return self.options.get(name, default)


def build_context(
    data,
    exp: PaperExpConfig,
    graph: Graph | None = None,
    seed: int = 0,
    options: dict | None = None,
) -> ExperimentContext:
    """Materialize the shared experiment context from a ClientDataset."""
    from repro.graphs.topology import make_graph

    n, s = data.n_clients, data.n_clusters
    if graph is None:
        graph = make_graph(exp.graph_kind, n, exp.avg_degree, seed=seed)
    k_model = jax.random.PRNGKey(seed)
    params0, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        exp.model, k_model, data.x.shape[-1], data.n_classes
    )

    def model_init(k):
        p, *_ = make_classifier(exp.model, k, data.x.shape[-1], data.n_classes)
        return p

    return ExperimentContext(
        exp=exp, graph=graph, n_clients=n, n_clusters=s,
        model_init=model_init, apply_fn=apply_fn, loss_fn=loss_fn,
        pel_fn=pel_fn, acc_fn=acc_fn, model_bytes=tree_bytes(params0),
        train={"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)},
        test={"inputs": jnp.asarray(data.x_test),
              "targets": jnp.asarray(data.y_test)},
        options=dict(options or {}),
    )


# --------------------------------------------------------------------------
# Communication accounting
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommModel:
    """How the driver accounts bytes: a static per-round cost, or "tracked"
    (FedSPD's data-dependent point-to-point cost accumulated in state)."""

    kind: str               # "static" | "tracked"
    per_round_bytes: float = 0.0


def edges_bytes(graph: Graph, model_b: int, models: int = 1) -> float:
    """Multicast DFL round cost: each client sends ``models`` models per
    directed neighbor link."""
    directed_links = float(graph.adj.sum() - graph.n)
    return directed_links * model_b * models


def star_bytes(n: int, model_b: int, models: int = 1) -> float:
    """Centralized round cost: every client uploads + downloads per model."""
    return 2.0 * n * model_b * models


# --------------------------------------------------------------------------
# Method protocol
# --------------------------------------------------------------------------


class Method:
    """Base adapter. Subclasses implement init/make_step/personalize/
    comm_model; evaluate and extras have sensible defaults.

    ``supports_param_plane`` declares that the adapter implements the
    packed (S, N, X) parameter-plane representation (core/packing.py) end
    to end — init packs, the step runs flat, personalize/evaluate unpack at
    the API boundary. The driver hard-errors on ``param_plane=True`` for
    adapters that have not opted in (a silent pytree fallback would
    misreport the benchmark matrix). Every built-in method supports it.

    ``supports_dynamic_graph`` declares that the adapter's step accepts a
    TRACED per-round (N, N) adjacency as a fifth argument —
    ``step(state, train, key, lr, adj)`` — the scenario engine's
    time-varying topologies / link dropout / per-seed graphs
    (experiments/scenarios.py). The driver hard-errors when a dynamic
    scenario targets an adapter that has not opted in.

    ``init``/``personalize``/``evaluate`` accept ``train=`` overriding
    ``ctx.train`` — the stacked-data driver path (``run_method_batch`` with
    per-seed datasets) maps a (k, N, M, ...) data stack over these, so
    adapters that consume training data outside the step (FedSPD's seeded
    init and final phase, pFedMe's personalization epochs) see seed i's
    own dataset. ``train=None`` (every static call site) means ctx.train."""

    name: str = ""
    centralized: bool = False
    supports_param_plane: bool = False
    supports_dynamic_graph: bool = False

    @staticmethod
    def _train(ctx: ExperimentContext, train):
        return ctx.train if train is None else train

    def _pack_spec(self, ctx: ExperimentContext):
        """The per-run PackSpec when ``param_plane`` is on, else None.
        Static per context — derived once from the model's eval_shape and
        stashed in the per-run options dict (init/make_step/personalize/
        evaluate all come through here)."""
        if not ctx.opt("param_plane", False):
            return None
        if not self.supports_param_plane:
            raise ValueError(
                f"method {self.name!r} does not support param_plane=True; "
                "set supports_param_plane after porting its state onto the "
                "packed (S, N, X) plane (core/packing.py)"
            )
        spec = ctx.options.get("_pack_spec")
        if spec is None:
            sds = jax.eval_shape(ctx.model_init, jax.random.PRNGKey(0))
            spec = make_pack_spec(sds)
            ctx.options["_pack_spec"] = spec
        return spec

    def _channel(self, ctx: ExperimentContext):
        """The run's comm channel (comm/codecs) when a compressing codec
        is configured, else None. ``codec="fp32"`` maps to None so the
        uncompressed code paths stay bit-exact. Compression operates on
        packed plane slices, so it requires ``param_plane=True`` (the
        driver enables it automatically when ``comm`` is set)."""
        cfg = ctx.opt("comm")
        if cfg is None or cfg.codec == "fp32":
            return None
        ps = self._pack_spec(ctx)
        if ps is None:
            raise ValueError(
                f"comm codec {cfg.codec!r} operates on the packed "
                "parameter plane; run with param_plane=True (run_method "
                "enables it automatically when comm is set)"
            )
        ch = ctx.options.get("_channel")
        if ch is None:
            ch = make_channel(cfg, ps.size)
            ctx.options["_channel"] = ch
        return ch

    def _with_ef(self, ctx: ExperimentContext, state, prefix=None):
        """Attach the error-feedback residual to a NamedTuple state's
        ``ef`` field when the run's channel carries one (no-op otherwise).
        ``prefix`` is the residual's batch shape — default one message per
        client; FedEM passes (S, N) for its all-stacks exchange."""
        ch = self._channel(ctx)
        if ch is None or not ch.has_ef:
            return state
        return state._replace(
            ef=ch.init_residual(prefix or (ctx.n_clients,))
        )

    def cohort_axes(self, ctx: ExperimentContext, state):
        """Per-field client-axis map for cohort subsampling
        (``RunConfig.cohort_size``): a state-shaped container giving, for
        each field, the axis that indexes clients (None = a global field
        — round counter, key, comm counter — threaded through whole).
        The driver gathers the K active rows along these axes, runs the
        UNCHANGED step on the compact cohort, and scatters back, so
        inactive clients' rows are carried bit-untouched. Methods opt in
        by overriding."""
        raise ValueError(
            f"method {self.name!r} does not support cohort subsampling "
            "(RunConfig.cohort_size) — its adapter defines no per-field "
            "client-axis map; override Method.cohort_axes"
        )

    def init(self, ctx: ExperimentContext, key: jax.Array, train=None):
        raise NotImplementedError

    def make_step(self, ctx: ExperimentContext) -> Callable:
        raise NotImplementedError

    def personalize(self, ctx: ExperimentContext, state, key: jax.Array,
                    train=None):
        raise NotImplementedError

    def comm_model(self, ctx: ExperimentContext) -> CommModel:
        raise NotImplementedError

    def evaluate(self, ctx: ExperimentContext, state, key: jax.Array,
                 on: dict, train=None) -> jnp.ndarray:
        """Per-client accuracy of the personalized models on ``on``."""
        params = self.personalize(ctx, state, key, train=train)
        return per_client_eval(ctx.acc_fn, params, on)

    def extras(self, ctx: ExperimentContext, state, aux: dict) -> dict:
        return {}

    def mixing(self, ctx: ExperimentContext) -> jnp.ndarray:
        """(N, N) averaging weights: exact global mean (centralized) or
        Metropolis gossip over the client graph (decentralized)."""
        return mixing_matrix(ctx.graph, ctx.n_clients, self.centralized)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Method] = {}


def register(method: Method) -> Method:
    assert method.name, "method must set a name"
    assert method.name not in _REGISTRY, f"duplicate method {method.name!r}"
    _REGISTRY[method.name] = method
    return method


def get_method(name: str) -> Method:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown method {name!r}; available: {available_methods()}"
        )
    return _REGISTRY[name]


def available_methods() -> tuple[str, ...]:
    return tuple(_REGISTRY)


# --------------------------------------------------------------------------
# FedSPD (the paper's algorithm)
# --------------------------------------------------------------------------


class FedSPDMethod(Method):
    """Paper Algorithm 1 behind the registry contract. ``mode`` selects the
    gossip wiring (dense Eq. (1) matrix vs edge-colored permute schedule);
    ``ctx.options['gossip_backend']`` additionally routes execution through
    the Pallas streaming kernel or the shard_map ppermute schedule, and
    ``ctx.options['param_plane']`` switches the round step onto the packed
    (S, N, X) parameter plane (core/packing.py)."""

    supports_param_plane = True
    supports_dynamic_graph = True

    def __init__(self, name: str, mode: str = "dense"):
        self.name = name
        self.mode = mode

    def _fcfg(self, ctx: ExperimentContext) -> FedSPDConfig:
        exp = ctx.exp
        return FedSPDConfig(
            n_clients=ctx.n_clients, n_clusters=ctx.n_clusters, tau=exp.tau,
            batch=exp.batch, lr0=exp.lr0, lr_decay=exp.lr_decay,
            tau_final=ctx.opt("tau_final", exp.tau_final),
            dp_clip=ctx.opt("dp_clip", 0.0),
            dp_noise_multiplier=ctx.opt("dp_noise_multiplier", 0.0),
        )

    def _spec(self, ctx: ExperimentContext) -> GossipSpec:
        return GossipSpec.from_graph(
            ctx.graph, mode=ctx.opt("mode", self.mode),
            cos_align_threshold=ctx.opt("cos_align_threshold", -1.0),
        )

    def _sparse(self, ctx: ExperimentContext):
        """The run's SparseConfig (core/sparse) when one is configured.
        Masks live on the packed X axis, so an enabled config requires the
        plane; the ppermute backend ships raw plane rows and is out."""
        sp = ctx.opt("sparse")
        if sp is None:
            return None
        if self._pack_spec(ctx) is None:
            raise ValueError(
                f"sparse training (density={sp.density}) runs on the "
                "packed parameter plane; set RunConfig(param_plane=True) "
                "(run_method enables it automatically when sparse is set)"
            )
        if sp.enabled and ctx.opt("gossip_backend", "reference") == "ppermute":
            raise ValueError(
                "sparse training is not available on the ppermute backend "
                "— the collective schedule ships raw plane rows, not "
                "masked payloads"
            )
        return sp

    def init(self, ctx, key, train=None):
        state = seeded_init(key, ctx.model_init, self._fcfg(ctx), ctx.loss_fn,
                            self._train(ctx, train))
        ps = self._pack_spec(ctx)
        # pytree -> packed plane at the API boundary (models re-enter
        # pytree form only for eval/checkpoint)
        if ps is not None:
            state = self._with_ef(ctx, pack_state(state, ps))
        sp = self._sparse(ctx)
        if sp is not None:
            # masks are carried even at density=1.0 (all-ones, no key
            # draw) so the state structure is uniform across densities
            state = state._replace(mask=init_masks(
                jax.random.fold_in(key, 0x3A5C),
                ctx.n_clients, ps.size, sp,
            ))
        return state

    def make_step(self, ctx):
        spec = self._spec(ctx)
        ps = self._pack_spec(ctx)
        comm = ctx.opt("comm")
        mix_fn = make_mix_fn(
            spec, backend=ctx.opt("gossip_backend", "reference"),
            plane=ps is not None, comm=comm,
        )
        step = make_round_step(ctx.loss_fn, ctx.pel_fn, spec, self._fcfg(ctx),
                               mix_fn=mix_fn, pack_spec=ps,
                               model_bytes=ctx.model_bytes, comm=comm,
                               sparse=self._sparse(ctx))

        def wrapped(state, train, key, lr, adj=None):
            # FedSPD's round step carries its own key and lr schedule in
            # state; driver-provided key/lr are for the uniform signature.
            # ``adj`` is the scenario engine's traced per-round adjacency.
            del key, lr
            return step(state, train, adj)

        return wrapped

    def cohort_axes(self, ctx, state):
        """FedSPD's packed state on the plane: centers (S, N, X) → axis 1;
        u (N, S) / z (N, M) / ef (N, X) → axis 0; round/key/comm_bytes are
        global. Cohort subsampling needs the dense wiring (the permute
        edge coloring and the ppermute device placement are sized to the
        full client axis) and the packed plane (the compact gather is a
        plane-row gather)."""
        from repro.core.fedspd import FedSPDState

        if self._pack_spec(ctx) is None:
            raise ValueError(
                "cohort subsampling runs on the packed (S, N, X) "
                "parameter plane; set RunConfig(param_plane=True)"
            )
        if ctx.opt("mode", self.mode) != "dense":
            raise ValueError(
                "cohort subsampling needs the dense gossip wiring — the "
                "permute edge coloring is sized to the full client axis"
            )
        if ctx.opt("gossip_backend", "reference") == "ppermute":
            raise ValueError(
                "cohort subsampling is not available on the ppermute "
                "backend (one device per client row)"
            )
        return FedSPDState(
            centers=1, u=0, z=0, round=None, key=None, comm_bytes=None,
            ef=None if state.ef is None else 0,
            mask=None if state.mask is None else 0,
        )

    def personalize(self, ctx, state, key, train=None):
        del key
        return final_phase(state, ctx.loss_fn, self._train(ctx, train),
                           self._fcfg(ctx), pack_spec=self._pack_spec(ctx))

    def comm_model(self, ctx):
        return CommModel(kind="tracked")

    def extras(self, ctx, state, aux):
        import numpy as np

        out = {"u": np.asarray(state.u)}
        if aux and "consensus" in aux:
            out["consensus"] = np.asarray(aux["consensus"])
        return out


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------


class FedAvgMethod(Method):
    supports_param_plane = True

    def __init__(self, name: str, centralized: bool):
        self.name = name
        self.centralized = centralized

    def init(self, ctx, key, train=None):
        del train  # random init only
        params = jax.vmap(ctx.model_init)(
            jax.random.split(key, ctx.n_clients)
        )
        ps = self._pack_spec(ctx)
        if ps is None:
            return params
        ch = self._channel(ctx)
        ef = (ch.init_residual((ctx.n_clients,))
              if ch is not None and ch.has_ef else None)
        return join_ef(pack(params, ps), ef, ch)

    def make_step(self, ctx):
        return fedavg.make_step(
            ctx.loss_fn, self.mixing(ctx), tau=ctx.exp.tau,
            batch=ctx.exp.batch, pack_spec=self._pack_spec(ctx),
            gossip_backend=ctx.opt("gossip_backend", "reference"),
            channel=self._channel(ctx),
        )

    def personalize(self, ctx, state, key, train=None):
        del key, train
        return fedavg.personalized_params(state,
                                          pack_spec=self._pack_spec(ctx),
                                          channel=self._channel(ctx))

    def comm_model(self, ctx):
        per_round = (star_bytes(ctx.n_clients, ctx.model_bytes)
                     if self.centralized
                     else edges_bytes(ctx.graph, ctx.model_bytes))
        return CommModel(kind="static", per_round_bytes=per_round)


class LocalMethod(Method):
    name = "local"
    supports_param_plane = True

    def init(self, ctx, key, train=None):
        del train  # random init only
        params = jax.vmap(ctx.model_init)(
            jax.random.split(key, ctx.n_clients)
        )
        ps = self._pack_spec(ctx)
        return pack(params, ps) if ps is not None else params

    def make_step(self, ctx):
        return local.make_step(ctx.loss_fn, tau=ctx.exp.tau,
                               batch=ctx.exp.batch,
                               pack_spec=self._pack_spec(ctx))

    def personalize(self, ctx, state, key, train=None):
        del key, train
        return local.personalized_params(state,
                                         pack_spec=self._pack_spec(ctx))

    def comm_model(self, ctx):
        return CommModel(kind="static", per_round_bytes=0.0)


class FedEMMethod(Method):
    """Trains and exchanges ALL S cluster models per round (S× comm);
    personalized prediction is the u-weighted probability mixture, so
    ``evaluate`` overrides the personalize-based default."""

    supports_param_plane = True

    def __init__(self, name: str, centralized: bool):
        self.name = name
        self.centralized = centralized

    def init(self, ctx, key, train=None):
        del train  # random init only
        state = fedem.init_state(key, ctx.model_init, ctx.n_clients,
                                 ctx.n_clusters,
                                 pack_spec=self._pack_spec(ctx))
        # FedEM ships every one of the S stacks each round
        return self._with_ef(ctx, state,
                             prefix=(ctx.n_clusters, ctx.n_clients))

    def make_step(self, ctx):
        return fedem.make_step(
            ctx.loss_fn, ctx.pel_fn, self.mixing(ctx), tau=ctx.exp.tau,
            batch=ctx.exp.batch, s_clusters=ctx.n_clusters,
            pack_spec=self._pack_spec(ctx),
            gossip_backend=ctx.opt("gossip_backend", "reference"),
            channel=self._channel(ctx),
        )

    def personalize(self, ctx, state, key, train=None):
        """Eq.-(2)-style projection (u-weighted parameter average) — used
        for serve-style export; accuracy uses the probability mixture."""
        del key, train
        ps = self._pack_spec(ctx)
        if ps is not None:
            plane = state.centers  # (S, N, X)
            mixed = jnp.einsum("ns,snx->nx", state.u.astype(plane.dtype),
                               plane)
            return unpack(mixed, ps)
        centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1),
                                  state.centers)
        return jax.vmap(tree_weighted_sum)(centers_nc, state.u)

    def evaluate(self, ctx, state, key, on, train=None):
        del key, train
        return fedem.personalized_accuracy(ctx.apply_fn, state, on,
                                           pack_spec=self._pack_spec(ctx))

    def comm_model(self, ctx):
        s = ctx.n_clusters
        per_round = (star_bytes(ctx.n_clients, ctx.model_bytes, models=s)
                     if self.centralized
                     else edges_bytes(ctx.graph, ctx.model_bytes, models=s))
        return CommModel(kind="static", per_round_bytes=per_round)

    def extras(self, ctx, state, aux):
        import numpy as np

        return {"u": np.asarray(state.u)}


class IFCAMethod(Method):
    supports_param_plane = True

    def __init__(self, name: str, centralized: bool):
        self.name = name
        self.centralized = centralized

    def init(self, ctx, key, train=None):
        del train  # random init only
        state = ifca.init_state(key, ctx.model_init, ctx.n_clients,
                                ctx.n_clusters,
                                pack_spec=self._pack_spec(ctx))
        return self._with_ef(ctx, state)

    def make_step(self, ctx):
        g_eff = ctx.graph if not self.centralized else complete(ctx.n_clients)
        spec = GossipSpec.from_graph(g_eff, mode="dense")
        return ifca.make_step(ctx.loss_fn, ctx.pel_fn, spec,
                              tau=ctx.exp.tau, batch=ctx.exp.batch,
                              pack_spec=self._pack_spec(ctx),
                              channel=self._channel(ctx))

    def personalize(self, ctx, state, key, train=None):
        del key, train
        return ifca.personalized_params(state,
                                        pack_spec=self._pack_spec(ctx))

    def comm_model(self, ctx):
        per_round = (star_bytes(ctx.n_clients, ctx.model_bytes)
                     if self.centralized
                     else edges_bytes(ctx.graph, ctx.model_bytes))
        return CommModel(kind="static", per_round_bytes=per_round)

    def extras(self, ctx, state, aux):
        import numpy as np

        return {"choice": np.asarray(state.choice)}


class FedSoftMethod(Method):
    supports_param_plane = True

    def __init__(self, name: str, centralized: bool):
        self.name = name
        self.centralized = centralized

    def init(self, ctx, key, train=None):
        del train  # random init only
        state = fedsoft.init_state(key, ctx.model_init, ctx.n_clients,
                                   ctx.n_clusters,
                                   pack_spec=self._pack_spec(ctx))
        return self._with_ef(ctx, state)

    def make_step(self, ctx):
        return fedsoft.make_step(
            ctx.loss_fn, ctx.pel_fn, self.mixing(ctx), tau=ctx.exp.tau,
            batch=ctx.exp.batch, s_clusters=ctx.n_clusters,
            pack_spec=self._pack_spec(ctx),
            channel=self._channel(ctx),
        )

    def personalize(self, ctx, state, key, train=None):
        del key, train
        return fedsoft.personalized_params(state,
                                           pack_spec=self._pack_spec(ctx))

    def comm_model(self, ctx):
        per_round = (star_bytes(ctx.n_clients, ctx.model_bytes)
                     if self.centralized
                     else edges_bytes(ctx.graph, ctx.model_bytes))
        return CommModel(kind="static", per_round_bytes=per_round)

    def extras(self, ctx, state, aux):
        import numpy as np

        return {"u": np.asarray(state.u)}


class PFedMeMethod(Method):
    supports_param_plane = True

    def __init__(self, name: str, centralized: bool):
        self.name = name
        self.centralized = centralized

    def init(self, ctx, key, train=None):
        del train  # random init only
        state = pfedme.init_state(key, n_clients=ctx.n_clients,
                                  model_init=ctx.model_init,
                                  pack_spec=self._pack_spec(ctx))
        return self._with_ef(ctx, state)

    def make_step(self, ctx):
        return pfedme.make_step(
            ctx.loss_fn, self.mixing(ctx), tau=ctx.exp.tau,
            batch=ctx.exp.batch, pack_spec=self._pack_spec(ctx),
            gossip_backend=ctx.opt("gossip_backend", "reference"),
            channel=self._channel(ctx),
        )

    def personalize(self, ctx, state, key, train=None):
        return pfedme.personalized_params(state, ctx.loss_fn,
                                          self._train(ctx, train), key,
                                          batch=ctx.exp.batch,
                                          pack_spec=self._pack_spec(ctx))

    def comm_model(self, ctx):
        per_round = (star_bytes(ctx.n_clients, ctx.model_bytes)
                     if self.centralized
                     else edges_bytes(ctx.graph, ctx.model_bytes))
        return CommModel(kind="static", per_round_bytes=per_round)


# --------------------------------------------------------------------------
# Registrations: FedSPD + all six baselines, dfl_ and cfl_ variants
# --------------------------------------------------------------------------

register(FedSPDMethod("fedspd", mode="dense"))
register(FedSPDMethod("fedspd_permute", mode="permute"))  # beyond-paper schedule
register(LocalMethod())
for _cls, _base in (
    (FedAvgMethod, "fedavg"),
    (FedEMMethod, "fedem"),
    (IFCAMethod, "ifca"),
    (FedSoftMethod, "fedsoft"),
    (PFedMeMethod, "pfedme"),
):
    register(_cls(f"dfl_{_base}", centralized=False))
    register(_cls(f"cfl_{_base}", centralized=True))
