"""Scenario engine: the paper's full experimental protocol as driver input.

The paper's headline claim is accuracy under LOW-connectivity networks, and
its Appendix B.2.4 stresses dynamically rewired ER/BA/RGG topologies; DisPFL
(Dai et al., 2022) uses time-varying random graphs as the standard
decentralized-PFL stress test, and DeceFL (Yuan et al., 2021) motivates
robustness to per-round link failures. A ``Scenario`` bundles those axes —
plus the per-seed-dataset repeated-trials protocol of the paper's
Tables 2–3 — into one declarative object the experiment driver
(experiments/runner.py) resolves into traced inputs:

- ``graph_schedule``: a per-round topology sequence
  (graphs/topology.GraphSchedule, e.g. ``rewire_schedule(...)``), or a raw
  (rounds, N, N) adjacency stack. The round step receives each round's
  (N, N) matrix as a TRACED argument (core/fedspd.make_round_step); under
  ``RunConfig(scan_rounds=True)`` the whole stack rides the scan xs — so a
  rewire sweep costs ONE jit compile either way.
- ``dropout``: per-round Bernoulli link failures on top of whatever the
  schedule (or the static graph) provides. The mask is drawn IN-STEP from
  ``fold_in(PRNGKey(seed), round)`` (``bernoulli_drop`` below) — no
  host-side (rounds, N, N) stack is materialized, and the Python-loop and
  scan-rolled engines see the identical mask stream. Masked rows are
  renormalized inside the step and the comm accounting charges only
  surviving links — a dropped edge costs zero wire bytes.
- ``data_stack``: marks a ``run_method_batch`` call whose ``data`` is a
  per-seed sequence of datasets (the old table23 protocol: k seeds ×
  k datasets × k graphs in one compile). Passing a list of datasets
  implies it; the flag exists so a Scenario fully describes a protocol.
- ``system``: a ``heterogeneity.ClientSystemModel`` — per-client compute
  speeds, straggler timeouts, Bernoulli/Markov availability, and
  stale-gossip decay. A straggling or unavailable client drops from the
  traced adjacency exactly like a failed link (zero wire bytes, plane
  row carried bit-untouched); a stale sender's mixing weight decays by
  ``gamma**staleness``. The draws are key-derived in-step like dropout,
  so both engines see the identical straggler stream.

Static per-edge machinery (the permute/ppermute edge coloring, the
shard_map collective schedule) is built once from the UNION graph over the
whole PRE-dropout schedule; each round's traced adjacency masks the
inactive edges.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.topology import (
    Graph,
    GraphSchedule,
    stack_schedule,
    symmetric_mask_drop,
    union_graph,
)


def bernoulli_drop(adj: jnp.ndarray, key: jax.Array,
                   p: float) -> jnp.ndarray:
    """One round of TRACED Bernoulli link failures (the in-step analogue
    of graphs/topology.drop_edges — both share
    ``topology.symmetric_mask_drop``, so the host and traced semantics
    cannot drift): each undirected off-diagonal link of ``adj`` drops
    with probability ``p``; one draw per edge (failures are symmetric),
    diagonal kept (a client always keeps its own model). The driver
    calls this with ``fold_in(PRNGKey(scenario.seed), round)``, so the
    mask stream is a pure function of (scenario seed, round index) —
    identical under the Python-loop and lax.scan engines."""
    n = adj.shape[-1]
    u = jnp.triu(jax.random.uniform(key, (n, n), jnp.float32), k=1)
    u = u + u.T
    return symmetric_mask_drop(adj, u, p, xp=jnp)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative experiment scenario; see the module docstring.

    ``seed`` drives the in-step dropout mask stream (the graph schedule
    carries its own seed; ``system`` — the client-heterogeneity model —
    carries its own too). ``schedule_stack``/``resolve`` turn the
    scenario into the driver's traced inputs: a PRE-dropout
    (rounds, N, N) adjacency stack plus the union graph the static
    machinery is built from.
    """

    graph_schedule: Any = None   # GraphSchedule | (rounds, N, N) ndarray
    dropout: float = 0.0         # per-round Bernoulli edge-drop probability
    data_stack: bool = False     # run_method_batch data is per-seed stacked
    seed: int = 0                # dropout mask stream
    system: Any = None           # heterogeneity.ClientSystemModel

    def __post_init__(self):
        # out-of-range dropout would silently produce a degenerate mask
        # (p > 1 drops everything, p < 0 drops nothing) — fail loudly at
        # construction instead; ClientSystemModel validates its own
        # probabilities the same way
        if not 0.0 <= float(self.dropout) <= 1.0:
            raise ValueError(
                f"Scenario.dropout={self.dropout!r} must be in [0, 1]"
            )

    @property
    def dynamic(self) -> bool:
        """Whether the scenario varies the effective topology (and
        therefore needs the traced-adjacency round step)."""
        return (self.graph_schedule is not None or self.dropout > 0.0
                or self.system is not None)

    def schedule_stack(self, rounds: int) -> np.ndarray | None:
        """The (rounds, N, N) PRE-dropout schedule (None without one).
        Shorter schedules cycle (a schedule is a topology PROCESS, not a
        fixed-length tape); longer ones are cropped to the run."""
        if self.graph_schedule is None:
            return None
        adjs = (self.graph_schedule.adjs
                if isinstance(self.graph_schedule, GraphSchedule)
                else np.asarray(self.graph_schedule, dtype=np.float32))
        return stack_schedule(adjs, rounds)

    def resolve(self, graph: Graph | None,
                rounds: int) -> tuple[np.ndarray, Graph]:
        """(rounds, N, N) PRE-dropout adjacency stack + the union graph.

        ``graph`` is the static base topology, required when the scenario
        has no ``graph_schedule`` (dropout-only scenarios mask it).
        Dropout is NOT applied here — it is a key-derived in-step draw
        (``bernoulli_drop``), so the wiring (edge colorings, collective
        schedules) is built from every link that can come back.
        """
        if not self.dynamic:
            raise ValueError("static scenario: nothing to resolve")
        stack = self.schedule_stack(rounds)
        if stack is None:
            if graph is None:
                raise ValueError(
                    "a dropout- or heterogeneity-only scenario needs "
                    "the base graph"
                )
            stack = np.broadcast_to(
                graph.adj, (rounds,) + graph.adj.shape
            ).astype(np.float32)
        return np.ascontiguousarray(stack, dtype=np.float32), \
            union_graph(stack)
