"""Scenario engine: the paper's full experimental protocol as driver input.

The paper's headline claim is accuracy under LOW-connectivity networks, and
its Appendix B.2.4 stresses dynamically rewired ER/BA/RGG topologies; DisPFL
(Dai et al., 2022) uses time-varying random graphs as the standard
decentralized-PFL stress test, and DeceFL (Yuan et al., 2021) motivates
robustness to per-round link failures. A ``Scenario`` bundles those axes —
plus the per-seed-dataset repeated-trials protocol of the paper's
Tables 2–3 — into one declarative object the experiment driver
(experiments/runner.py) resolves into traced inputs:

- ``graph_schedule``: a per-round topology sequence
  (graphs/topology.GraphSchedule, e.g. ``rewire_schedule(...)``), or a raw
  (rounds, N, N) adjacency stack. The round step receives each round's
  (N, N) matrix as a TRACED argument (core/fedspd.make_round_step), so the
  whole schedule — and a 10-round rewire sweep — costs ONE jit compile.
- ``dropout``: per-round Bernoulli link failures on top of whatever the
  schedule (or the static graph) provides. Masked rows are renormalized
  inside the step and the comm accounting charges only surviving links —
  a dropped edge costs zero wire bytes.
- ``data_stack``: marks a ``run_method_batch`` call whose ``data`` is a
  per-seed sequence of datasets (the old table23 protocol: k seeds ×
  k datasets × k graphs in one compile). Passing a list of datasets
  implies it; the flag exists so a Scenario fully describes a protocol.

Static per-edge machinery (the permute/ppermute edge coloring, the
shard_map collective schedule) is built once from the UNION graph over the
whole schedule; each round's traced adjacency masks the inactive edges.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.graphs.topology import (
    Graph,
    GraphSchedule,
    drop_edges,
    union_graph,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative experiment scenario; see the module docstring.

    ``seed`` drives the dropout mask stream (the graph schedule carries its
    own seed). ``resolve`` turns the scenario into the driver's traced
    inputs: a (rounds, N, N) per-round adjacency stack plus the union graph
    the static machinery is built from.
    """

    graph_schedule: Any = None   # GraphSchedule | (rounds, N, N) ndarray
    dropout: float = 0.0         # per-round Bernoulli edge-drop probability
    data_stack: bool = False     # run_method_batch data is per-seed stacked
    seed: int = 0                # dropout mask stream

    @property
    def dynamic(self) -> bool:
        """Whether the scenario varies the topology (and therefore needs
        the traced-adjacency round step)."""
        return self.graph_schedule is not None or self.dropout > 0.0

    def _schedule_stack(self, rounds: int) -> Optional[np.ndarray]:
        if self.graph_schedule is None:
            return None
        adjs = (self.graph_schedule.adjs
                if isinstance(self.graph_schedule, GraphSchedule)
                else np.asarray(self.graph_schedule, dtype=np.float32))
        if adjs.ndim != 3 or adjs.shape[1] != adjs.shape[2]:
            raise ValueError(
                f"graph_schedule must stack (rounds, N, N) adjacencies; "
                f"got shape {adjs.shape}"
            )
        # shorter schedules cycle (a schedule is a topology PROCESS, not a
        # fixed-length tape); longer ones are cropped to the run
        reps = -(-rounds // adjs.shape[0])
        return np.tile(adjs, (reps, 1, 1))[:rounds]

    def resolve(self, graph: Optional[Graph],
                rounds: int) -> tuple[np.ndarray, Graph]:
        """(rounds, N, N) traced adjacency stack + the union graph.

        ``graph`` is the static base topology, required when the scenario
        has no ``graph_schedule`` (dropout-only scenarios mask it).
        The union is taken over the PRE-dropout schedule: dropout models
        transient link failures, so the wiring (edge colorings, collective
        schedules) must cover every link that can come back.
        """
        if not self.dynamic:
            raise ValueError("static scenario: nothing to resolve")
        stack = self._schedule_stack(rounds)
        if stack is None:
            if graph is None:
                raise ValueError(
                    "a dropout-only scenario needs the base graph"
                )
            stack = np.broadcast_to(
                graph.adj, (rounds,) + graph.adj.shape
            ).astype(np.float32)
        union = union_graph(stack)
        if self.dropout > 0.0:
            rng = np.random.default_rng(self.seed)
            stack = np.stack([drop_edges(a, self.dropout, rng)
                              for a in stack])
        return np.ascontiguousarray(stack, dtype=np.float32), union
