"""Client-system heterogeneity: traced stragglers, availability, staleness.

FedSPD's headline claim is accuracy under LOW-connectivity networks, but
real decentralized deployments degrade along a second axis: *client-system*
heterogeneity — slow devices, flaky availability, stale exchange. DeceFL
(Yuan et al., 2021) motivates exactly this robustness story; FLSim's
per-client ``TimeOutSimulator``/channel models define the standard
simulation surface. This module is that surface for the scenario engine:

- ``ClientSystemModel`` declares per-client compute speeds (explicit
  multipliers or a slow-client fraction), a per-round time budget with
  lognormal jitter (straggler timeouts), Bernoulli or two-state Markov
  availability, and a stale-gossip decay ``staleness_gamma``.
- ``het_round`` draws ONE round of it — key-derived
  (``fold_in(key, round)`` in the driver), so the Python-loop and
  lax.scan engines see the identical straggler stream and a
  heterogeneity sweep stays one jit compile.
- ``apply_client_weights`` folds the resulting per-client activity
  weights into the traced adjacency: an inactive client's row AND column
  vanish before ``fedspd_weight_matrix`` renormalization (it neither
  sends nor receives — exactly like a failed link, zero wire bytes), and
  a stale sender's column is decayed by ``gamma**staleness`` so
  chronically slow clients fade from consensus instead of poisoning it.
- ``masked_client_step`` carries an inactive client's state rows
  BIT-untouched through the round, reusing the ``Method.cohort_axes``
  client-axis contract the cohort-gather machinery already defines.

The staleness counter rides the round carry (``HetCarry``): it resets to
zero on a successful exchange and increments while a client is timed out
or unavailable. A returning client is down-weighted ONCE by its age
(``w = active * gamma**staleness``, staleness measured BEFORE the reset),
then rejoins at full weight.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class HetCarry(NamedTuple):
    """Per-client heterogeneity state threaded through the round carry
    (the loop engine threads it eagerly; ``scan_rounds=True`` puts it in
    the lax.scan carry next to the parameter plane)."""

    stale: jnp.ndarray  # (N,) int32 — rounds since the last successful
    #                     exchange (0 = exchanged last round)
    avail: jnp.ndarray  # (N,) float32 — Markov up/down state (1 = up);
    #                     all-ones under Bernoulli / no availability model


def _check_prob(name: str, v: float) -> None:
    if not 0.0 <= float(v) <= 1.0:
        raise ValueError(
            f"ClientSystemModel.{name}={v!r} must be in [0, 1]"
        )


@dataclasses.dataclass(frozen=True)
class ClientSystemModel:
    """Declarative per-client compute-speed / availability / staleness
    model; resolved by the experiment driver via ``Scenario.system``.

    speed           explicit (N,) per-client speed multipliers (1.0 =
                    nominal; 0.25 = 4x slower), or None to derive from
                    ``slow_fraction``/``slow_factor``
    slow_fraction   fraction of clients that are slow (chosen host-side
                    from ``seed``; deterministic count round(f*N))
    slow_factor     slowdown multiplier for the slow clients (>= 1)
    time_budget     per-round wall budget in nominal-client round units;
                    a client whose round time 1/speed (x jitter) exceeds
                    it STRAGGLES this round. 0 disables timeouts.
    jitter          lognormal sigma on per-round compute time (0 = none)
    p_unavailable   i.i.d. Bernoulli per-round unavailability
    markov          (p_fail, p_recover) two-state availability chain —
                    bursty outages; mutually exclusive with
                    ``p_unavailable``
    staleness_gamma stale-gossip decay in (0, 1]: a sender's mixing
                    weight is scaled by gamma**staleness (1.0 = off)
    seed            drives the slow-client choice AND the traced
                    timeout/availability stream (fold_in(round) in-step)
    """

    speed: Any = None
    slow_fraction: float = 0.0
    slow_factor: float = 4.0
    time_budget: float = 0.0
    jitter: float = 0.0
    p_unavailable: float = 0.0
    markov: Optional[tuple] = None
    staleness_gamma: float = 1.0
    seed: int = 0

    def __post_init__(self):
        _check_prob("slow_fraction", self.slow_fraction)
        _check_prob("p_unavailable", self.p_unavailable)
        if self.markov is not None:
            if len(self.markov) != 2:
                raise ValueError(
                    "ClientSystemModel.markov must be (p_fail, p_recover);"
                    f" got {self.markov!r}"
                )
            _check_prob("markov[0] (p_fail)", self.markov[0])
            _check_prob("markov[1] (p_recover)", self.markov[1])
            if self.p_unavailable > 0.0:
                raise ValueError(
                    "ClientSystemModel: p_unavailable and markov are "
                    "mutually exclusive availability models"
                )
        if self.slow_factor < 1.0:
            raise ValueError(
                f"ClientSystemModel.slow_factor={self.slow_factor!r} "
                "must be >= 1 (it is a slowdown)"
            )
        if self.time_budget < 0.0:
            raise ValueError(
                f"ClientSystemModel.time_budget={self.time_budget!r} "
                "must be >= 0 (0 disables straggler timeouts)"
            )
        if self.jitter < 0.0:
            raise ValueError(
                f"ClientSystemModel.jitter={self.jitter!r} must be >= 0"
            )
        if not 0.0 < float(self.staleness_gamma) <= 1.0:
            raise ValueError(
                "ClientSystemModel.staleness_gamma="
                f"{self.staleness_gamma!r} must be in (0, 1]"
            )

    @property
    def has_stragglers(self) -> bool:
        return self.time_budget > 0.0

    @property
    def has_availability(self) -> bool:
        return self.p_unavailable > 0.0 or self.markov is not None

    def resolve_speeds(self, n: int) -> np.ndarray:
        """Host-side (N,) speed multipliers: explicit ``speed`` wins;
        otherwise round(slow_fraction*N) clients chosen from ``seed``
        run at 1/slow_factor. Host-side like the topology generators —
        WHO is slow is experiment configuration; WHETHER a slow client
        misses the budget each round is the traced draw (het_round)."""
        if self.speed is not None:
            arr = np.asarray(self.speed, dtype=np.float32)
            if arr.shape != (n,):
                raise ValueError(
                    f"ClientSystemModel.speed must have shape ({n},); "
                    f"got {arr.shape}"
                )
            if (arr <= 0.0).any():
                raise ValueError(
                    "ClientSystemModel.speed multipliers must be positive"
                )
            return arr
        speeds = np.ones(n, dtype=np.float32)
        k = int(round(float(self.slow_fraction) * n))
        if k:
            rng = np.random.default_rng(self.seed)
            idx = rng.choice(n, size=k, replace=False)
            speeds[idx] = np.float32(1.0 / self.slow_factor)
        return speeds

    def init_carry(self, n: int) -> HetCarry:
        """Round-0 carry: nobody stale, everybody up."""
        return HetCarry(stale=jnp.zeros((n,), jnp.int32),
                        avail=jnp.ones((n,), jnp.float32))


def het_round(model: ClientSystemModel, speeds: jnp.ndarray,
              carry: HetCarry, key: jax.Array) -> tuple[HetCarry, jnp.ndarray]:
    """One round of the heterogeneity process: (carry', weights).

    ``weights`` is the (N,) per-client activity vector: 0 for a client
    that timed out or is unavailable this round, ``gamma**staleness``
    (staleness BEFORE this round's reset) for one that exchanges. All
    draws come from ``key`` — the driver passes ``fold_in(key, round)``,
    so the stream is a pure function of (model seed, round index) and is
    identical under the Python-loop and lax.scan engines.
    """
    n = carry.stale.shape[0]
    k_time, k_avail = jax.random.split(key)
    if model.has_stragglers:
        t = 1.0 / speeds
        if model.jitter > 0.0:
            t = t * jnp.exp(
                model.jitter * jax.random.normal(k_time, (n,), jnp.float32)
            )
        timely = (t <= model.time_budget).astype(jnp.float32)
    else:
        timely = jnp.ones((n,), jnp.float32)
    if model.markov is not None:
        p_fail, p_recover = (float(p) for p in model.markov)
        u = jax.random.uniform(k_avail, (n,), jnp.float32)
        up = carry.avail > 0.0
        avail = jnp.where(up, u >= p_fail, u < p_recover).astype(jnp.float32)
    elif model.p_unavailable > 0.0:
        u = jax.random.uniform(k_avail, (n,), jnp.float32)
        avail = (u >= model.p_unavailable).astype(jnp.float32)
    else:
        avail = jnp.ones((n,), jnp.float32)
    active = timely * avail
    gamma = float(model.staleness_gamma)
    if gamma < 1.0:
        w = active * jnp.power(
            jnp.float32(gamma), carry.stale.astype(jnp.float32)
        )
    else:
        w = active
    stale = jnp.where(active > 0.0, 0, carry.stale + 1).astype(jnp.int32)
    return HetCarry(stale=stale, avail=avail), w


def apply_client_weights(adj: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Fold per-client activity weights into the traced adjacency.

    An inactive client (w == 0) loses its row AND column — it neither
    receives (its mixing row becomes e_i after the weight-matrix diagonal
    restore) nor sends (no neighbor averages it in), and the comm
    accounting charges it zero wire bytes. An active-but-stale sender's
    column is scaled by its decayed weight, which
    ``fedspd_weight_matrix`` row-renormalizes into the mixture.
    """
    recv = (w > 0.0).astype(adj.dtype)
    return adj * recv[..., :, None] * w.astype(adj.dtype)[..., None, :]


def restore_inactive(old, new, axes, keep):
    """Carry inactive clients' state rows BIT-untouched through a round.

    ``old``/``new`` are same-shaped state namedtuples; ``axes`` maps each
    field to its client axis (the ``Method.cohort_axes`` contract: None =
    global field, kept from ``new``); ``keep`` is the (N,) active mask.
    A where-select, not an arithmetic blend — the carried rows are the
    exact old bits.
    """

    def keep_old(o, v, ax):
        if o is None or ax is None:
            return v
        shape = (1,) * ax + (-1,) + (1,) * (o.ndim - ax - 1)
        return jnp.where(keep.reshape(shape), v, o)

    return type(old)(*(keep_old(o, v, a)
                       for o, v, a in zip(old, new, axes)))


def masked_client_step(step, axes):
    """Run a traced-adjacency step under per-client activity weights.

    ``axes`` maps each state field to its client axis — the SAME
    ``Method.cohort_axes`` contract cohort subsampling uses (None =
    global field, threaded through whole). The wrapper folds this
    round's weights (the LAST extra argument) into the traced adjacency
    via ``apply_client_weights``, runs the wrapped step unchanged, then
    restores inactive clients' state rows bit-untouched
    (``restore_inactive``): a straggling client's plane row is carried,
    not recomputed — its local training never ran as far as the
    experiment is concerned.

    Composes outside the cohort wrapper: inactive cohort members are
    masked out of the gathered (K, K) sub-adjacency and their scattered
    rows are restored here; clients outside the cohort were never
    touched, so the restore is a no-op for them either way.
    """

    def steph(state, train, key, lr, adj, *rest):
        *inner, aw = rest
        new, aux = step(state, train, key, lr,
                        apply_client_weights(adj, aw), *inner)
        return restore_inactive(state, new, axes, aw > 0.0), aux

    return steph
