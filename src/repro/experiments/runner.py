"""Experiment driver: one shared round loop for every registered method.

``run_method`` resolves an algorithm through the method registry
(experiments/registry.py) and owns everything the old per-method if/elif
branches used to hand-roll: the jitted round loop, eval cadence, curve
collection, and communication accounting.  Adding an algorithm is now a
registry entry — the driver never changes.

``run_method_batch`` is the multi-seed fast path: states for all seeds are
initialized with vmap, the round step is vmapped over the seed axis and
jitted ONCE, so a k-seed sweep costs one compilation plus k× the per-round
arithmetic (which XLA batches through the same fused program).  Passing a
SEQUENCE of datasets (one per seed) switches on the stacked-data variant —
the paper's Tables 2–3 repeated-trials protocol (k seeds × k datasets ×
k graphs) in the same single compile, with the data (and, for methods that
support dynamic graphs, a per-seed graph stack) mapped over the seed axis.

Both drivers accept a ``scenario`` (experiments/scenarios.py): time-varying
graph schedules and Bernoulli link dropout resolve to a per-round TRACED
(rounds, N, N) adjacency stack fed to the step, so a whole dynamic-topology
sweep still compiles exactly once.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import ClientDataset
from repro.experiments.registry import (
    ExperimentContext,
    Method,
    available_methods,
    build_context,
    get_method,
)
from repro.experiments.scenarios import Scenario
from repro.graphs.topology import Graph, union_graph

METHODS = available_methods()


@dataclasses.dataclass
class RunResult:
    method: str
    acc_per_client: np.ndarray  # (N,)
    mean_acc: float
    std_acc: float
    comm_bytes: float   # LOGICAL bytes: what the uncompressed exchange
    #                     would have moved (original dtypes)
    wire_bytes: float   # PHYSICAL bytes under the run's comm codec —
    #                     equals comm_bytes when no compression is on
    curve: list  # [(round, mean train acc)]
    wall_s: float
    extras: dict


def _lr_schedule(exp: PaperExpConfig):
    return lambda t: exp.lr0 * (exp.lr_decay ** t)


def _check_param_plane(m: Method, options: dict) -> None:
    """Hard error instead of a silent pytree fallback: a run that ASKED for
    the packed engine must either get it or fail loudly (benchmark results
    would otherwise misattribute the representation)."""
    if options.get("param_plane") and not m.supports_param_plane:
        raise ValueError(
            f"method {m.name!r} does not support param_plane=True — its "
            "adapter has not been ported onto the packed (S, N, X) "
            "parameter plane (core/packing.py); drop param_plane or port "
            "the adapter and set supports_param_plane"
        )


def _normalize_comm(options: dict) -> None:
    """A compressing codec operates on packed plane slices, so ``comm``
    implies ``param_plane=True`` — enabled here unless the caller
    explicitly pinned the pytree engine (then fail loudly: silently
    flipping the representation would misattribute benchmark results)."""
    comm = options.get("comm")
    if comm is None or comm.codec == "fp32":
        return
    if options.get("param_plane") is False:
        raise ValueError(
            f"comm codec {comm.codec!r} requires the packed parameter "
            "plane, but param_plane=False was requested — drop one of the "
            "two (fp32 is the only pytree-safe codec)"
        )
    options.setdefault("param_plane", True)


def _merge_options(options: dict | None, gossip_mode, gossip_backend,
                   param_plane, comm) -> dict:
    """The convenience kwargs both drivers share, folded into ``options``
    (explicit options win — the kwargs are shorthand, not overrides)."""
    options = dict(options or {})
    if gossip_mode is not None:
        options.setdefault("mode", gossip_mode)
    if gossip_backend is not None:
        options.setdefault("gossip_backend", gossip_backend)
    if param_plane is not None:
        options.setdefault("param_plane", param_plane)
    if comm is not None:
        options.setdefault("comm", comm)
    _normalize_comm(options)
    return options


def _require_dynamic_graph(m: Method, what: str) -> None:
    if not m.supports_dynamic_graph:
        raise ValueError(
            f"method {m.name!r} does not support {what} — its step does "
            "not accept the traced per-round adjacency (set "
            "supports_dynamic_graph after threading adj through the step; "
            "see experiments/scenarios.py)"
        )


def _resolve_scenario(m: Method, scenario: Scenario | None, graph,
                      exp: PaperExpConfig, data, seed: int):
    """(adj_rounds (rounds, N, N) jnp array | None, ctx graph). A dynamic
    scenario replaces the context graph with the UNION graph over the
    schedule, so static per-edge machinery (permute/ppermute colorings)
    covers every edge the traced adjacencies can activate."""
    if scenario is None or not scenario.dynamic:
        return None, graph
    _require_dynamic_graph(m, "dynamic-topology scenarios")
    base = graph
    if base is None and scenario.graph_schedule is None:
        from repro.graphs.topology import make_graph

        base = make_graph(exp.graph_kind, data.n_clients, exp.avg_degree,
                          seed=seed)
    stack, union = scenario.resolve(base, exp.rounds)
    return jnp.asarray(stack), union


def _n_compiles(step) -> int:
    """Jit cache size — diagnostic only: _cache_size is a private jax API,
    so don't let its absence on other jax versions fail a finished run."""
    try:
        return int(getattr(step, "_cache_size", lambda: -1)())
    except Exception:
        return -1


def _wire_bytes(ctx: ExperimentContext, logical: float) -> float:
    """Physical bytes for this run's codec: the per-message compression
    ratio is static (comm/codecs.Channel.wire_model_bytes over the
    logical model bytes), so scaling the logical count is EXACT — every
    transmitted message is one model-sized plane slice."""
    cfg = ctx.opt("comm")
    if cfg is None or cfg.codec == "fp32":
        return logical
    ch = ctx.options.get("_channel")
    if ch is None:
        from repro.comm.codecs import make_channel

        ch = make_channel(cfg, ctx.options["_pack_spec"].size)
    return logical * ch.wire_ratio(ctx.model_bytes)


def _donate_argnums(options: dict) -> tuple:
    """The round step is jitted with the state argument donated by default:
    the (S, N, X) plane (or pytree state) is aliased input→output, so the
    round updates it in place instead of allocating a second copy each
    call. ``options={"donate": False}`` opts out (e.g. when a caller holds
    onto intermediate states)."""
    return (0,) if options.get("donate", True) else ()


def _result(method: Method, ctx: ExperimentContext, state, aux, acc,
            curve, t0, n_compiles=None) -> RunResult:
    comm_model = method.comm_model(ctx)
    if comm_model.kind == "tracked":
        comm = float(state.comm_bytes)
    else:
        comm = comm_model.per_round_bytes * ctx.exp.rounds
    extras = method.extras(ctx, state, aux)
    if n_compiles is not None:
        extras["n_compiles"] = n_compiles
    acc = np.asarray(acc)
    return RunResult(
        method=method.name,
        acc_per_client=acc,
        mean_acc=float(acc.mean()),
        std_acc=float(acc.std()),
        comm_bytes=comm,
        wire_bytes=_wire_bytes(ctx, comm),
        curve=curve,
        wall_s=time.time() - t0,
        extras=extras,
    )


def run_method(
    method: str,
    data: ClientDataset,
    exp: PaperExpConfig,
    graph: Graph | None = None,
    seed: int = 0,
    eval_every: int = 10,
    gossip_mode: str | None = None,
    gossip_backend: str | None = None,
    param_plane: bool | None = None,
    comm=None,
    scenario: Scenario | None = None,
    options: dict | None = None,
) -> RunResult:
    """Run one method for ``exp.rounds`` rounds; returns RunResult.

    ``gossip_mode`` (FedSPD) / ``gossip_backend`` / ``param_plane`` /
    ``comm`` are conveniences forwarded into ``options``
    ("dense"/"permute" wiring; "reference"/"pallas"/"ppermute" execution;
    packed (S, N, X) plane vs pytree state — valid for EVERY method id,
    ValueError for adapters that have not opted in; comm/codecs.CommConfig
    wire codec — valid for every method id, implies ``param_plane=True``
    for compressing codecs, and reported as ``RunResult.wire_bytes``
    alongside the logical ``comm_bytes``).  Arbitrary per-method knobs go
    through ``options``; ``options={"donate": False}`` disables the
    default in-place state donation of the jitted round step.

    ``scenario`` (experiments/scenarios.py) activates the dynamic-topology
    engine: the resolved (rounds, N, N) adjacency stack is fed to the step
    one TRACED (N, N) slice per round — time-varying rewire schedules and
    Bernoulli link dropout run through ONE jit compile
    (``extras["n_compiles"]`` records the cache size), and dropped links
    cost zero wire bytes in the comm accounting.
    """
    t0 = time.time()
    m = get_method(method)
    options = _merge_options(options, gossip_mode, gossip_backend,
                             param_plane, comm)
    _check_param_plane(m, options)
    adj_rounds, graph = _resolve_scenario(m, scenario, graph, exp, data, seed)
    ctx = build_context(data, exp, graph=graph, seed=seed, options=options)

    key = jax.random.PRNGKey(seed)
    k_init, k_run, k_eval = jax.random.split(key, 3)
    state = m.init(ctx, k_init)
    step = jax.jit(m.make_step(ctx), donate_argnums=_donate_argnums(options))
    lr_at = _lr_schedule(exp)

    curve = []
    aux = None
    for r in range(exp.rounds):
        k_run, k = jax.random.split(k_run)
        if adj_rounds is None:
            state, aux = step(state, ctx.train, k, lr_at(r))
        else:
            state, aux = step(state, ctx.train, k, lr_at(r), adj_rounds[r])
        if r % eval_every == 0 or r == exp.rounds - 1:
            train_acc = m.evaluate(ctx, state, k_eval, ctx.train)
            curve.append((r, float(jnp.mean(train_acc))))

    acc = m.evaluate(ctx, state, k_eval, ctx.test)
    return _result(m, ctx, state, aux, acc, curve, t0,
                   n_compiles=_n_compiles(step))


def _stack_graphs(m: Method, graph, seeds):
    """Per-seed graphs (a sequence in ``graph``): stacked into a (k, N, N)
    traced adjacency vmapped over the seed axis; the context gets the
    union graph (static machinery must cover every seed's edges)."""
    if graph is None or isinstance(graph, Graph):
        return None, graph
    graphs = list(graph)
    if len(graphs) != len(seeds):
        raise ValueError(
            f"per-seed graphs: got {len(graphs)} graphs for "
            f"{len(seeds)} seeds"
        )
    _require_dynamic_graph(m, "per-seed graphs")
    adj = np.stack([g.adj for g in graphs]).astype(np.float32)
    return jnp.asarray(adj), union_graph(adj)


def _stack_data(data, seeds):
    """The stacked-data variant: ``data`` as a per-seed sequence of
    ClientDatasets becomes (k, N, M, ...) train/test stacks mapped over
    the seed axis (the paper's per-seed-dataset repeated-trials
    protocol). A single ClientDataset keeps the shared-data behaviour."""
    if isinstance(data, ClientDataset):
        return data, None, None
    datasets = list(data)
    if len(datasets) != len(seeds):
        raise ValueError(
            f"stacked data: got {len(datasets)} datasets for "
            f"{len(seeds)} seeds"
        )
    for d in datasets[1:]:
        if (d.x.shape != datasets[0].x.shape
                or d.n_classes != datasets[0].n_classes
                or d.n_clusters != datasets[0].n_clusters):
            raise ValueError(
                "stacked datasets must share shapes/classes/clusters "
                "(one fused XLA program runs every seed)"
            )
    train = {
        "inputs": jnp.asarray(np.stack([d.x for d in datasets])),
        "targets": jnp.asarray(np.stack([d.y for d in datasets])),
    }
    test = {
        "inputs": jnp.asarray(np.stack([d.x_test for d in datasets])),
        "targets": jnp.asarray(np.stack([d.y_test for d in datasets])),
    }
    return datasets[0], train, test


def run_method_batch(
    method: str,
    data,
    exp: PaperExpConfig,
    seeds=(0, 1, 2),
    graph: Graph | None = None,
    eval_every: int = 10,
    gossip_mode: str | None = None,
    gossip_backend: str | None = None,
    param_plane: bool | None = None,
    comm=None,
    scenario: Scenario | None = None,
    options: dict | None = None,
) -> list[RunResult]:
    """Multi-seed batched execution: ONE jit compile shared by all seeds.

    The per-seed state pytrees are stacked on a leading seed axis; the
    method's step runs under ``jax.vmap`` inside a single ``jax.jit``, so
    round r of every seed executes as one fused XLA program.  Returns one
    RunResult per seed; ``extras["n_compiles"]`` records the jit cache
    size (1 = shared).

    Accepts the same convenience kwargs as ``run_method`` (``gossip_mode``,
    ``gossip_backend``, ``param_plane``, ``comm``) — the two entry points
    take identical configuration.

    Three batching axes compose:

    - shared data + shared graph (the default): only the random state —
      model init, batch sampling, cluster selection — differs per seed;
    - stacked data: ``data`` as a SEQUENCE of per-seed ClientDatasets
      (or ``scenario.data_stack``) maps a (k, N, M, ...) data stack over
      the seed axis — the paper's Tables 2–3 per-seed-dataset protocol;
    - per-seed graphs: ``graph`` as a sequence stacks a (k, N, N) traced
      adjacency over the seed axis (methods with
      ``supports_dynamic_graph``; the context wiring uses the union
      graph). A dynamic ``scenario`` instead feeds one (N, N) slice of
      its (rounds, N, N) schedule per round, shared by every seed.
    """
    t0 = time.time()
    m = get_method(method)
    options = _merge_options(options, gossip_mode, gossip_backend,
                             param_plane, comm)
    _check_param_plane(m, options)
    if scenario is not None and scenario.data_stack \
            and isinstance(data, ClientDataset):
        raise ValueError(
            "scenario.data_stack=True needs a per-seed sequence of "
            "datasets in `data`"
        )
    base_data, train_stack, test_stack = _stack_data(data, seeds)
    adj_seeds, graph = _stack_graphs(m, graph, seeds)
    adj_rounds = None
    if scenario is not None and scenario.dynamic:
        if adj_seeds is not None:
            raise ValueError(
                "per-seed graphs and a dynamic scenario schedule are "
                "mutually exclusive (one traced adjacency per step)"
            )
        adj_rounds, graph = _resolve_scenario(
            m, scenario, graph, exp, base_data, int(seeds[0])
        )
    ctx = build_context(base_data, exp, graph=graph, seed=int(seeds[0]),
                        options=options)
    lr_at = _lr_schedule(exp)

    data_ax = None if train_stack is None else 0
    train_arg = ctx.train if train_stack is None else train_stack
    test_arg = ctx.test if test_stack is None else test_stack

    seed_keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    split3 = jax.vmap(lambda k: jax.random.split(k, 3))(seed_keys)  # (k, 3, 2)
    k_init, k_run, k_eval = split3[:, 0], split3[:, 1], split3[:, 2]

    states = jax.vmap(
        lambda k, tr: m.init(ctx, k, train=tr), in_axes=(0, data_ax)
    )(k_init, train_arg)
    # canonicalize weak types: an init-only weak-typed leaf (e.g. a
    # jnp.full without dtype) would force a second jit compile at round 2
    states = jax.tree.map(lambda l: l.astype(l.dtype), states)
    base_step = m.make_step(ctx)
    if adj_seeds is None and adj_rounds is None:
        step = jax.jit(
            jax.vmap(base_step, in_axes=(0, data_ax, 0, None)),
            donate_argnums=_donate_argnums(options),
        )
    else:
        adj_ax = 0 if adj_seeds is not None else None
        step = jax.jit(
            jax.vmap(base_step, in_axes=(0, data_ax, 0, None, adj_ax)),
            donate_argnums=_donate_argnums(options),
        )
    evaluate = jax.jit(
        jax.vmap(
            lambda state, key, on, tr: m.evaluate(ctx, state, key, on,
                                                  train=tr),
            in_axes=(0, 0, data_ax, data_ax),
        )
    )

    curves = [[] for _ in seeds]
    aux = None
    for r in range(exp.rounds):
        ks = jax.vmap(jax.random.split)(k_run)
        k_run, k = ks[:, 0], ks[:, 1]
        if adj_seeds is not None:
            states, aux = step(states, train_arg, k, lr_at(r), adj_seeds)
        elif adj_rounds is not None:
            states, aux = step(states, train_arg, k, lr_at(r), adj_rounds[r])
        else:
            states, aux = step(states, train_arg, k, lr_at(r))
        if r % eval_every == 0 or r == exp.rounds - 1:
            train_acc = evaluate(states, k_eval, train_arg, train_arg)
            for i in range(len(seeds)):
                curves[i].append((r, float(jnp.mean(train_acc[i]))))

    accs = np.asarray(evaluate(states, k_eval, test_arg, train_arg))  # (k, N)
    n_compiles = _n_compiles(step)
    results = []
    for i, _ in enumerate(seeds):
        state_i = jax.tree.map(lambda l: l[i], states)
        aux_i = jax.tree.map(lambda l: l[i], aux) if aux else aux
        results.append(
            _result(m, ctx, state_i, aux_i, accs[i], curves[i], t0,
                    n_compiles=n_compiles)
        )
    return results
