"""Experiment driver: one shared round loop for every registered method.

``run_method`` resolves an algorithm through the method registry
(experiments/registry.py) and owns everything the old per-method if/elif
branches used to hand-roll: the jitted round loop, eval cadence, curve
collection, and communication accounting.  Adding an algorithm is now a
registry entry — the driver never changes.

``run_method_batch`` is the multi-seed fast path: states for all seeds are
initialized with vmap, the round step is vmapped over the seed axis and
jitted ONCE, so a k-seed sweep costs one compilation plus k× the per-round
arithmetic (which XLA batches through the same fused program).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import ClientDataset
from repro.experiments.registry import (
    ExperimentContext,
    Method,
    available_methods,
    build_context,
    get_method,
)
from repro.graphs.topology import Graph

METHODS = available_methods()


@dataclasses.dataclass
class RunResult:
    method: str
    acc_per_client: np.ndarray  # (N,)
    mean_acc: float
    std_acc: float
    comm_bytes: float   # LOGICAL bytes: what the uncompressed exchange
    #                     would have moved (original dtypes)
    wire_bytes: float   # PHYSICAL bytes under the run's comm codec —
    #                     equals comm_bytes when no compression is on
    curve: list  # [(round, mean train acc)]
    wall_s: float
    extras: dict


def _lr_schedule(exp: PaperExpConfig):
    return lambda t: exp.lr0 * (exp.lr_decay ** t)


def _check_param_plane(m: Method, options: dict) -> None:
    """Hard error instead of a silent pytree fallback: a run that ASKED for
    the packed engine must either get it or fail loudly (benchmark results
    would otherwise misattribute the representation)."""
    if options.get("param_plane") and not m.supports_param_plane:
        raise ValueError(
            f"method {m.name!r} does not support param_plane=True — its "
            "adapter has not been ported onto the packed (S, N, X) "
            "parameter plane (core/packing.py); drop param_plane or port "
            "the adapter and set supports_param_plane"
        )


def _normalize_comm(options: dict) -> None:
    """A compressing codec operates on packed plane slices, so ``comm``
    implies ``param_plane=True`` — enabled here unless the caller
    explicitly pinned the pytree engine (then fail loudly: silently
    flipping the representation would misattribute benchmark results)."""
    comm = options.get("comm")
    if comm is None or comm.codec == "fp32":
        return
    if options.get("param_plane") is False:
        raise ValueError(
            f"comm codec {comm.codec!r} requires the packed parameter "
            "plane, but param_plane=False was requested — drop one of the "
            "two (fp32 is the only pytree-safe codec)"
        )
    options.setdefault("param_plane", True)


def _wire_bytes(ctx: ExperimentContext, logical: float) -> float:
    """Physical bytes for this run's codec: the per-message compression
    ratio is static (comm/codecs.Channel.wire_model_bytes over the
    logical model bytes), so scaling the logical count is EXACT — every
    transmitted message is one model-sized plane slice."""
    cfg = ctx.opt("comm")
    if cfg is None or cfg.codec == "fp32":
        return logical
    ch = ctx.options.get("_channel")
    if ch is None:
        from repro.comm.codecs import make_channel

        ch = make_channel(cfg, ctx.options["_pack_spec"].size)
    return logical * ch.wire_ratio(ctx.model_bytes)


def _donate_argnums(options: dict) -> tuple:
    """The round step is jitted with the state argument donated by default:
    the (S, N, X) plane (or pytree state) is aliased input→output, so the
    round updates it in place instead of allocating a second copy each
    call. ``options={"donate": False}`` opts out (e.g. when a caller holds
    onto intermediate states)."""
    return (0,) if options.get("donate", True) else ()


def _result(method: Method, ctx: ExperimentContext, state, aux, acc,
            curve, t0, n_compiles=None) -> RunResult:
    comm_model = method.comm_model(ctx)
    if comm_model.kind == "tracked":
        comm = float(state.comm_bytes)
    else:
        comm = comm_model.per_round_bytes * ctx.exp.rounds
    extras = method.extras(ctx, state, aux)
    if n_compiles is not None:
        extras["n_compiles"] = n_compiles
    acc = np.asarray(acc)
    return RunResult(
        method=method.name,
        acc_per_client=acc,
        mean_acc=float(acc.mean()),
        std_acc=float(acc.std()),
        comm_bytes=comm,
        wire_bytes=_wire_bytes(ctx, comm),
        curve=curve,
        wall_s=time.time() - t0,
        extras=extras,
    )


def run_method(
    method: str,
    data: ClientDataset,
    exp: PaperExpConfig,
    graph: Graph | None = None,
    seed: int = 0,
    eval_every: int = 10,
    gossip_mode: str | None = None,
    gossip_backend: str | None = None,
    param_plane: bool | None = None,
    comm=None,
    options: dict | None = None,
) -> RunResult:
    """Run one method for ``exp.rounds`` rounds; returns RunResult.

    ``gossip_mode`` (FedSPD) / ``gossip_backend`` / ``param_plane`` /
    ``comm`` are conveniences forwarded into ``options``
    ("dense"/"permute" wiring; "reference"/"pallas"/"ppermute" execution;
    packed (S, N, X) plane vs pytree state — valid for EVERY method id,
    ValueError for adapters that have not opted in; comm/codecs.CommConfig
    wire codec — valid for every method id, implies ``param_plane=True``
    for compressing codecs, and reported as ``RunResult.wire_bytes``
    alongside the logical ``comm_bytes``).  Arbitrary per-method knobs go
    through ``options``; ``options={"donate": False}`` disables the
    default in-place state donation of the jitted round step.
    """
    t0 = time.time()
    m = get_method(method)
    options = dict(options or {})
    if gossip_mode is not None:
        options.setdefault("mode", gossip_mode)
    if gossip_backend is not None:
        options.setdefault("gossip_backend", gossip_backend)
    if param_plane is not None:
        options.setdefault("param_plane", param_plane)
    if comm is not None:
        options.setdefault("comm", comm)
    _normalize_comm(options)
    _check_param_plane(m, options)
    ctx = build_context(data, exp, graph=graph, seed=seed, options=options)

    key = jax.random.PRNGKey(seed)
    k_init, k_run, k_eval = jax.random.split(key, 3)
    state = m.init(ctx, k_init)
    step = jax.jit(m.make_step(ctx), donate_argnums=_donate_argnums(options))
    lr_at = _lr_schedule(exp)

    curve = []
    aux = None
    for r in range(exp.rounds):
        k_run, k = jax.random.split(k_run)
        state, aux = step(state, ctx.train, k, lr_at(r))
        if r % eval_every == 0 or r == exp.rounds - 1:
            train_acc = m.evaluate(ctx, state, k_eval, ctx.train)
            curve.append((r, float(jnp.mean(train_acc))))

    acc = m.evaluate(ctx, state, k_eval, ctx.test)
    return _result(m, ctx, state, aux, acc, curve, t0)


def run_method_batch(
    method: str,
    data: ClientDataset,
    exp: PaperExpConfig,
    seeds=(0, 1, 2),
    graph: Graph | None = None,
    eval_every: int = 10,
    options: dict | None = None,
) -> list[RunResult]:
    """Multi-seed batched execution: ONE jit compile shared by all seeds.

    The per-seed state pytrees are stacked on a leading seed axis; the
    method's step runs under ``jax.vmap`` inside a single ``jax.jit``, so
    round r of every seed executes as one fused XLA program.  The data,
    graph, and method config are shared across seeds (only the random state
    — model init, batch sampling, cluster selection — differs), which is the
    paper's repeated-trials protocol.  Returns one RunResult per seed;
    ``extras["n_compiles"]`` records the jit cache size (1 = shared).
    """
    t0 = time.time()
    m = get_method(method)
    options = dict(options or {})
    _normalize_comm(options)
    _check_param_plane(m, options)
    ctx = build_context(data, exp, graph=graph, seed=int(seeds[0]),
                        options=options)
    lr_at = _lr_schedule(exp)

    seed_keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    split3 = jax.vmap(lambda k: jax.random.split(k, 3))(seed_keys)  # (k, 3, 2)
    k_init, k_run, k_eval = split3[:, 0], split3[:, 1], split3[:, 2]

    states = jax.vmap(lambda k: m.init(ctx, k))(k_init)
    # canonicalize weak types: an init-only weak-typed leaf (e.g. a
    # jnp.full without dtype) would force a second jit compile at round 2
    states = jax.tree.map(lambda l: l.astype(l.dtype), states)
    step = jax.jit(
        jax.vmap(m.make_step(ctx), in_axes=(0, None, 0, None)),
        donate_argnums=_donate_argnums(options),
    )
    evaluate = jax.jit(
        jax.vmap(
            lambda state, key, on: m.evaluate(ctx, state, key, on),
            in_axes=(0, 0, None),
        )
    )

    curves = [[] for _ in seeds]
    aux = None
    for r in range(exp.rounds):
        ks = jax.vmap(jax.random.split)(k_run)
        k_run, k = ks[:, 0], ks[:, 1]
        states, aux = step(states, ctx.train, k, lr_at(r))
        if r % eval_every == 0 or r == exp.rounds - 1:
            train_acc = evaluate(states, k_eval, ctx.train)  # (k, N)
            for i in range(len(seeds)):
                curves[i].append((r, float(jnp.mean(train_acc[i]))))

    accs = np.asarray(evaluate(states, k_eval, ctx.test))  # (k, N)
    # diagnostic only: _cache_size is a private jax API, so don't let its
    # absence on other jax versions fail a finished sweep
    cache_size = getattr(step, "_cache_size", lambda: -1)
    try:
        n_compiles = int(cache_size())
    except Exception:
        n_compiles = -1
    results = []
    for i, _ in enumerate(seeds):
        state_i = jax.tree.map(lambda l: l[i], states)
        aux_i = jax.tree.map(lambda l: l[i], aux) if aux else aux
        results.append(
            _result(m, ctx, state_i, aux_i, accs[i], curves[i], t0,
                    n_compiles=n_compiles)
        )
    return results
