"""End-to-end experiment runner: paper-scale FL runs on CPU.

Drives any of the implemented methods (FedSPD + the paper's six baselines,
decentralized and centralized variants) over a synthetic mixture
ClientDataset, reproducing the paper's experimental protocol:
per-client test accuracy (Tables 2–5), training curves (Fig. 2), accuracy
variance across clients (Fig. 3), and communication accounting (§6.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import fedavg, fedem, fedsoft, ifca, local, pfedme
from repro.baselines.common import mixing_matrix, per_client_eval
from repro.configs.paper_cnn import PaperExpConfig
from repro.core import (
    FedSPDConfig,
    GossipSpec,
    final_phase,
    init_state,
    make_round_step,
    seeded_init,
)
from repro.data.synthetic import ClientDataset
from repro.graphs.topology import Graph, make_graph
from repro.models.smallnets import make_classifier
from repro.utils.pytree import tree_bytes

METHODS = (
    "fedspd",
    "fedspd_permute",   # beyond-paper gossip schedule (same math)
    "dfl_fedavg", "cfl_fedavg",
    "dfl_fedem", "cfl_fedem",
    "dfl_ifca", "cfl_ifca",
    "dfl_fedsoft", "cfl_fedsoft",
    "dfl_pfedme", "cfl_pfedme",
    "local",
)


@dataclasses.dataclass
class RunResult:
    method: str
    acc_per_client: np.ndarray  # (N,)
    mean_acc: float
    std_acc: float
    comm_bytes: float
    curve: list  # [(round, mean train acc)]
    wall_s: float
    extras: dict


def _edges_bytes(graph: Graph, model_b: int, models: int = 1) -> float:
    """Multicast DFL round cost: each client sends `models` models per
    neighbor link (directed)."""
    directed_links = float(graph.adj.sum() - graph.n)
    return directed_links * model_b * models


def run_method(
    method: str,
    data: ClientDataset,
    exp: PaperExpConfig,
    graph: Graph | None = None,
    seed: int = 0,
    eval_every: int = 10,
    gossip_mode: str | None = None,
) -> RunResult:
    assert method in METHODS, method
    t0 = time.time()
    key = jax.random.PRNGKey(seed)
    k_model, k_run, k_eval = jax.random.split(key, 3)
    n, s = data.n_clients, data.n_clusters
    if graph is None:
        graph = make_graph(exp.graph_kind, n, exp.avg_degree, seed=seed)

    params0, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        exp.model, k_model, data.x.shape[-1], data.n_classes
    )
    model_b = tree_bytes(params0)

    train = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    test = {"inputs": jnp.asarray(data.x_test), "targets": jnp.asarray(data.y_test)}

    def model_init(k):
        p, *_ = make_classifier(exp.model, k, data.x.shape[-1], data.n_classes)
        return p

    centralized = method.startswith("cfl_")
    lr_at = lambda t: exp.lr0 * (exp.lr_decay ** t)  # noqa: E731
    curve = []
    extras = {}

    def train_acc(params):
        return float(jnp.mean(per_client_eval(acc_fn, params, train)))

    if method.startswith("fedspd"):
        mode = gossip_mode or ("permute" if method == "fedspd_permute" else "dense")
        fcfg = FedSPDConfig(
            n_clients=n, n_clusters=s, tau=exp.tau, batch=exp.batch,
            lr0=exp.lr0, lr_decay=exp.lr_decay, tau_final=exp.tau_final,
        )
        spec = GossipSpec.from_graph(graph, mode=mode)
        state = seeded_init(k_model, model_init, fcfg, loss_fn, train)
        step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))
        for r in range(exp.rounds):
            state, metrics = step(state, train)
            if r % eval_every == 0 or r == exp.rounds - 1:
                pers = final_phase(state, loss_fn, train, fcfg)
                curve.append((r, train_acc(pers)))
        personalized = final_phase(state, loss_fn, train, fcfg)
        comm = float(state.comm_bytes)
        extras["consensus"] = np.asarray(metrics["consensus"])
        extras["u"] = np.asarray(state.u)
        acc = per_client_eval(acc_fn, personalized, test)

    elif method.endswith("fedavg") or method == "local":
        if method == "local":
            step = jax.jit(local.make_step(loss_fn, tau=exp.tau, batch=exp.batch))
            comm_per_round = 0.0
        else:
            w = mixing_matrix(graph, n, centralized)
            step = jax.jit(fedavg.make_step(loss_fn, w, tau=exp.tau, batch=exp.batch))
            comm_per_round = (
                2.0 * n * model_b if centralized else _edges_bytes(graph, model_b)
            )
        params = jax.vmap(model_init)(jax.random.split(k_model, n))
        for r in range(exp.rounds):
            k_run, k = jax.random.split(k_run)
            params, _ = step(params, train, k, lr_at(r))
            if r % eval_every == 0 or r == exp.rounds - 1:
                curve.append((r, train_acc(params)))
        comm = comm_per_round * exp.rounds
        acc = per_client_eval(acc_fn, params, test)

    elif method.endswith("fedem"):
        w = mixing_matrix(graph, n, centralized)
        state = fedem.init_state(k_model, model_init, n, s)
        step = jax.jit(
            fedem.make_step(loss_fn, pel_fn, w, tau=exp.tau, batch=exp.batch,
                            s_clusters=s)
        )
        for r in range(exp.rounds):
            k_run, k = jax.random.split(k_run)
            state, _ = step(state, train, k, lr_at(r))
            if r % eval_every == 0 or r == exp.rounds - 1:
                curve.append((
                    r,
                    float(jnp.mean(fedem.personalized_accuracy(apply_fn, state, train))),
                ))
        comm = exp.rounds * (
            2.0 * n * model_b * s if centralized
            else _edges_bytes(graph, model_b, models=s)
        )
        acc = fedem.personalized_accuracy(apply_fn, state, test)
        extras["u"] = np.asarray(state.u)

    elif method.endswith("ifca"):
        g_eff = graph if not centralized else _complete(n)
        spec = GossipSpec.from_graph(g_eff, mode="dense")
        state = ifca.init_state(k_model, model_init, n, s)
        step = jax.jit(
            ifca.make_step(loss_fn, pel_fn, spec, tau=exp.tau, batch=exp.batch)
        )
        for r in range(exp.rounds):
            k_run, k = jax.random.split(k_run)
            state, _ = step(state, train, k, lr_at(r))
            if r % eval_every == 0 or r == exp.rounds - 1:
                curve.append((r, train_acc(ifca.personalized_params(state))))
        comm = exp.rounds * (
            2.0 * n * model_b if centralized else _edges_bytes(graph, model_b)
        )
        acc = per_client_eval(acc_fn, ifca.personalized_params(state), test)
        extras["choice"] = np.asarray(state.choice)

    elif method.endswith("fedsoft"):
        w = mixing_matrix(graph, n, centralized)
        state = fedsoft.init_state(k_model, model_init, n, s)
        step = jax.jit(
            fedsoft.make_step(loss_fn, pel_fn, w, tau=exp.tau, batch=exp.batch,
                              s_clusters=s)
        )
        for r in range(exp.rounds):
            k_run, k = jax.random.split(k_run)
            state, _ = step(state, train, k, lr_at(r))
            if r % eval_every == 0 or r == exp.rounds - 1:
                curve.append((r, train_acc(fedsoft.personalized_params(state))))
        comm = exp.rounds * (
            2.0 * n * model_b if centralized else _edges_bytes(graph, model_b)
        )
        acc = per_client_eval(acc_fn, fedsoft.personalized_params(state), test)
        extras["u"] = np.asarray(state.u)

    elif method.endswith("pfedme"):
        w = mixing_matrix(graph, n, centralized)
        state = pfedme.init_state(k_model, n_clients=n, model_init=model_init)
        step = jax.jit(
            pfedme.make_step(loss_fn, w, tau=exp.tau, batch=exp.batch)
        )
        for r in range(exp.rounds):
            k_run, k = jax.random.split(k_run)
            state, _ = step(state, train, k, lr_at(r))
            if r % eval_every == 0 or r == exp.rounds - 1:
                theta = pfedme.personalized_params(
                    state, loss_fn, train, k, batch=exp.batch
                )
                curve.append((r, train_acc(theta)))
        comm = exp.rounds * (
            2.0 * n * model_b if centralized else _edges_bytes(graph, model_b)
        )
        theta = pfedme.personalized_params(state, loss_fn, train, k_eval,
                                           batch=exp.batch)
        acc = per_client_eval(acc_fn, theta, test)

    else:  # pragma: no cover
        raise ValueError(method)

    acc = np.asarray(acc)
    return RunResult(
        method=method,
        acc_per_client=acc,
        mean_acc=float(acc.mean()),
        std_acc=float(acc.std()),
        comm_bytes=float(comm),
        curve=curve,
        wall_s=time.time() - t0,
        extras=extras,
    )


def _complete(n: int) -> Graph:
    from repro.graphs.topology import complete

    return complete(n)
