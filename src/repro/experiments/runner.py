"""Experiment driver: ONE shared round engine behind both entry points.

``run_method`` (single seed) and ``run_method_batch`` (multi-seed, vmapped)
are thin shims over the same internal driver (``_drive``): configuration
arrives as one frozen ``RunConfig`` (experiments/config.py), the method
resolves through the registry (experiments/registry.py), and the driver
owns the round engine, eval cadence, curve collection, communication
accounting, and seed batching.  The old seven loose kwargs are kept as
shims that emit ``DeprecationWarning``.

Two round engines share every closure:

- the Python loop (default): one jitted round-step dispatch per round —
  the historical engine, bit-stable against the committed seed fixtures;
- ``RunConfig(scan_rounds=True)``: the WHOLE experiment is one
  ``lax.scan``-rolled jitted program.  The round index / lr schedule / the
  (rounds, N, N) adjacency schedule ride the scan xs, the donated state
  (packed (S, N, X) plane, EF residuals, key) rides the carry, and the
  train-accuracy curve comes back as masked scan ys (``lax.cond`` at the
  static ``eval_every`` cadence).  One compile, one host dispatch,
  independent of ``rounds``.

Scenario link dropout (``Scenario.dropout``) is a key-derived IN-STEP
Bernoulli draw: the round index is folded into the scenario's PRNG key
inside the program, so both engines see the identical mask stream and a
dropout sweep never materializes a host-side (rounds, N, N) stack.

``RunConfig(cohort_size=K)`` adds per-round client subsampling on top of
either engine: K of N clients are gathered into a compact active plane
(state rows, data rows, the adjacency minor), the unchanged step runs at
size K, and results scatter back — inactive clients' rows are carried
bit-untouched and dropped links cost zero wire bytes (the comm accounting
reads the (K, K) sub-adjacency).

``Scenario.system`` (experiments/heterogeneity.ClientSystemModel) layers
client-system heterogeneity on either engine the same way: straggler
timeouts and Bernoulli/Markov availability are key-derived in-step draws
(``fold_in(key, round)``), an inactive client drops from the traced
adjacency exactly like a failed link (zero wire bytes, plane row carried
bit-untouched via the cohort-axes contract), and the per-client staleness
counter rides the round carry — threaded eagerly by the loop engine, in
the lax.scan carry under ``scan_rounds=True`` — decaying stale senders'
mixing weights by ``gamma**staleness``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import ClientDataset
from repro.experiments.config import RunConfig
from repro.experiments.registry import (
    ExperimentContext,
    Method,
    available_methods,
    build_context,
    get_method,
)
from repro.experiments.heterogeneity import (
    apply_client_weights,
    het_round,
    masked_client_step,
)
from repro.experiments.scenarios import Scenario, bernoulli_drop
from repro.graphs.topology import Graph, union_graph
from repro.telemetry import compile_count, step_annotation
from repro.telemetry.metrics import flatten_centers, make_collector

METHODS = available_methods()

_UNSET = object()   # distinguishes "not passed" from an explicit None


@dataclasses.dataclass
class RunResult:
    method: str
    acc_per_client: np.ndarray  # (N,)
    mean_acc: float
    std_acc: float
    comm_bytes: float   # LOGICAL bytes: what the uncompressed exchange
    #                     would have moved (original dtypes)
    wire_bytes: float   # PHYSICAL bytes under the run's comm codec —
    #                     equals comm_bytes when no compression is on
    curve: list  # [(round, mean train acc)]
    wall_s: float
    extras: dict
    telemetry: dict | None = None  # RunConfig.telemetry payload:
    #                                {"rounds": R, "streams": {name:
    #                                (R, ...) arrays}} — see
    #                                telemetry/config.py for the streams


def _lr_schedule(exp: PaperExpConfig):
    return lambda t: exp.lr0 * (exp.lr_decay ** t)


def _check_param_plane(m: Method, options: dict) -> None:
    """Hard error instead of a silent pytree fallback: a run that ASKED for
    the packed engine must either get it or fail loudly (benchmark results
    would otherwise misattribute the representation)."""
    if options.get("param_plane") and not m.supports_param_plane:
        raise ValueError(
            f"method {m.name!r} does not support param_plane=True — its "
            "adapter has not been ported onto the packed (S, N, X) "
            "parameter plane (core/packing.py); drop param_plane or port "
            "the adapter and set supports_param_plane"
        )


def _require_dynamic_graph(m: Method, what: str) -> None:
    if not m.supports_dynamic_graph:
        raise ValueError(
            f"method {m.name!r} does not support {what} — its step does "
            "not accept the traced per-round adjacency (set "
            "supports_dynamic_graph after threading adj through the step; "
            "see experiments/scenarios.py)"
        )


def _coerce_cfg(cfg: RunConfig | None, legacy: dict, entry: str) -> RunConfig:
    """Fold the deprecated loose kwargs into a RunConfig (shim path)."""
    passed = {k: v for k, v in legacy.items() if v is not _UNSET}
    if not passed:
        return cfg if cfg is not None else RunConfig()
    if cfg is not None:
        raise ValueError(
            f"{entry}: pass configuration either as cfg=RunConfig(...) or "
            f"as the legacy loose kwargs, not both (got {sorted(passed)})"
        )
    warnings.warn(
        f"{entry}: the loose kwargs {sorted(passed)} are deprecated; pass "
        "cfg=RunConfig(...) instead (README 'Running experiments' has the "
        "migration table)",
        DeprecationWarning, stacklevel=3,
    )
    return RunConfig(**passed)


def _resolve_scenario(m: Method, scenario: Scenario | None, graph,
                      exp: PaperExpConfig, data, seed: int, adj_seeds=None):
    """(adj_rounds, adj_const, drop_p, drop_key, ctx graph).

    A schedule resolves to a PRE-dropout (rounds, N, N) stack (scan xs /
    host-indexed per round) and replaces the context graph with the UNION
    graph, so static per-edge machinery (permute/ppermute colorings)
    covers every edge the traced adjacencies can activate.  A dropout-only
    scenario keeps the base adjacency as a per-round CONSTANT — the
    Bernoulli mask is drawn in-step from ``fold_in(drop_key, round)``, so
    no per-round stack is ever materialized host-side.
    """
    if scenario is None or not scenario.dynamic:
        return None, None, 0.0, None, graph
    if adj_seeds is not None:
        raise ValueError(
            "per-seed graphs and a dynamic scenario schedule are "
            "mutually exclusive (one traced adjacency per step)"
        )
    _require_dynamic_graph(m, "dynamic-topology scenarios")
    drop_p = float(scenario.dropout)
    drop_key = (jax.random.PRNGKey(int(scenario.seed))
                if drop_p > 0.0 else None)
    if scenario.graph_schedule is None:
        base = graph
        if base is None:
            from repro.graphs.topology import make_graph

            base = make_graph(exp.graph_kind, data.n_clients,
                              exp.avg_degree, seed=seed)
        return None, jnp.asarray(base.adj, jnp.float32), drop_p, drop_key, \
            base
    stack = scenario.schedule_stack(exp.rounds)
    return jnp.asarray(stack), None, drop_p, drop_key, union_graph(stack)


def _wire_bytes(ctx: ExperimentContext, logical: float) -> float:
    """Physical bytes for this run's codec: the per-message compression
    ratio is static (comm/codecs.Channel.wire_model_bytes over the
    logical model bytes), so scaling the logical count is EXACT — every
    transmitted message is one model-sized plane slice.

    Sparse runs (core/sparse; density < 1) ship the mask-then-encode
    format instead: nnz payload + support bitmap per message
    (comm/codecs.sparse_wire_model_bytes), also static given density."""
    cfg = ctx.opt("comm")
    sp = ctx.opt("sparse")
    if sp is not None and sp.enabled:
        from repro.comm.codecs import sparse_wire_model_bytes

        x = ctx.options["_pack_spec"].size
        per_msg = sparse_wire_model_bytes(cfg, x, sp.k_active(x))
        return logical * (per_msg / float(ctx.model_bytes))
    if cfg is None or cfg.codec == "fp32":
        return logical
    ch = ctx.options.get("_channel")
    if ch is None:
        from repro.comm.codecs import make_channel

        ch = make_channel(cfg, ctx.options["_pack_spec"].size)
    return logical * ch.wire_ratio(ctx.model_bytes)


def _donate_argnums(options: dict) -> tuple:
    """The round program is jitted with the state argument donated by
    default: the (S, N, X) plane (or pytree state) is aliased
    input→output, so each round (or the whole scan carry) updates it in
    place instead of allocating a second copy. ``RunConfig(donate=False)``
    opts out (e.g. when a caller holds onto intermediate states)."""
    return (0,) if options.get("donate", True) else ()


def _result(method: Method, ctx: ExperimentContext, state, aux, acc,
            curve, t0, n_compiles=None, n_dispatches=None,
            staleness=None, telemetry=None) -> RunResult:
    comm_model = method.comm_model(ctx)
    if comm_model.kind == "tracked":
        comm = float(state.comm_bytes)
    else:
        comm = comm_model.per_round_bytes * ctx.exp.rounds
    extras = method.extras(ctx, state, aux)
    if n_compiles is not None:
        extras["n_compiles"] = n_compiles
    if n_dispatches is not None:
        extras["n_dispatches"] = n_dispatches
    if staleness is not None:
        # final per-client staleness counters (heterogeneity scenarios):
        # 0 = exchanged in the last round, k = k rounds out of contact
        extras["staleness"] = staleness
    if ctx.opt("keep_state"):
        # serve-export path (experiments/export.py): hand back the final
        # method state + its PackSpec so export_run can lift the cluster
        # plane without re-deriving the run's packing
        extras["state"] = state
        extras["pack_spec"] = ctx.options.get("_pack_spec")
    acc = np.asarray(acc)
    return RunResult(
        method=method.name,
        acc_per_client=acc,
        mean_acc=float(acc.mean()),
        std_acc=float(acc.std()),
        comm_bytes=comm,
        wire_bytes=_wire_bytes(ctx, comm),
        curve=curve,
        wall_s=time.time() - t0,
        extras=extras,
        telemetry=telemetry,
    )


# --------------------------------------------------------------------------
# Cohort subsampling (RunConfig.cohort_size)
# --------------------------------------------------------------------------


def _cohort_indices(key, n: int, k: int) -> jnp.ndarray:
    """This round's active cohort: K of N clients, SORTED so gather and
    scatter are order-stable and inactive rows come back bit-untouched."""
    return jnp.sort(jax.random.permutation(key, n)[:k])


def _cohort_step(step, axes):
    """Run a dynamic-graph step on a compact K-client cohort.

    ``axes`` maps each state field to its client axis (None = global
    field, threaded through whole — round counter, key, comm counter).
    The wrapper gathers the active rows of the state, the training data,
    and the adjacency minor, runs the UNCHANGED step at size K, and
    scatters the results back; comm accounting inside the step sees the
    (K, K) sub-adjacency, so inactive clients cost zero wire bytes."""

    def take(v, ax, idx):
        return v if v is None or ax is None else jnp.take(v, idx, axis=ax)

    def put(full, sub, ax, idx):
        if full is None or ax is None:
            return sub
        if ax == 0:
            return full.at[idx].set(sub)
        return full.at[(slice(None),) * ax + (idx,)].set(sub)

    def stepc(state, train, key, lr, adj, active):
        sub = type(state)(*(take(v, a, active)
                            for v, a in zip(state, axes)))
        sub_train = jax.tree.map(lambda l: jnp.take(l, active, axis=0),
                                 train)
        sub_adj = jnp.take(jnp.take(adj, active, axis=0), active, axis=1)
        sub, aux = step(sub, sub_train, key, lr, sub_adj)
        new = type(state)(*(put(v, s, a, active)
                            for v, s, a in zip(state, sub, axes)))
        return new, aux

    return stepc


# --------------------------------------------------------------------------
# The shared driver
# --------------------------------------------------------------------------


def _drive(entry: str, method: str, data, exp: PaperExpConfig, graph,
           seeds, cfg: RunConfig):
    t0 = time.time()
    batched = entry == "run_method_batch"
    m = get_method(method)
    options = cfg.resolve_options()
    _check_param_plane(m, options)
    scenario = cfg.scenario
    rounds, eval_every = exp.rounds, cfg.eval_every

    # ---- data / graph / scenario resolution --------------------------------
    adj_seeds = None
    if batched:
        seeds = tuple(int(s) for s in seeds)
        if scenario is not None and scenario.data_stack \
                and isinstance(data, ClientDataset):
            raise ValueError(
                f"{entry}: scenario.data_stack=True needs a per-seed "
                "sequence of datasets in `data`"
            )
        base_data, train_stack, test_stack = _stack_data(data, seeds, entry)
        adj_seeds, graph = _stack_graphs(m, graph, seeds, entry)
    else:
        seeds = (int(seeds),)
        base_data, train_stack, test_stack = data, None, None

    adj_rounds, adj_const, drop_p, drop_key, graph = _resolve_scenario(
        m, scenario, graph, exp, base_data, seeds[0], adj_seeds=adj_seeds
    )
    ctx = build_context(base_data, exp, graph=graph, seed=seeds[0],
                        options=options)
    lr_at = _lr_schedule(exp)
    # lr precomputed host-side as an f32 tape: the loop indexes it, the
    # scan consumes it as xs — both engines see bit-identical rates
    lrs = np.asarray([lr_at(r) for r in range(rounds)], np.float32)

    # ---- keys & per-seed state init ----------------------------------------
    data_ax = None if train_stack is None else 0
    train_arg = ctx.train if train_stack is None else train_stack
    test_arg = ctx.test if test_stack is None else test_stack
    if batched:
        seed_keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
        split3 = jax.vmap(lambda k: jax.random.split(k, 3))(seed_keys)
        k_init, k_run, k_eval = split3[:, 0], split3[:, 1], split3[:, 2]
        states = jax.vmap(
            lambda k, tr: m.init(ctx, k, train=tr), in_axes=(0, data_ax)
        )(k_init, train_arg)
        # canonicalize weak types: an init-only weak-typed leaf (e.g. a
        # jnp.full without dtype) would force a second jit compile at
        # round 2 (and break the scan carry's aval match)
        states = jax.tree.map(lambda l: l.astype(l.dtype), states)
    else:
        key = jax.random.PRNGKey(seeds[0])
        k_init, k_run, k_eval = jax.random.split(key, 3)
        states = m.init(ctx, k_init)

    # ---- cohort subsampling ------------------------------------------------
    cohort = cfg.cohort_size
    cohort_key = None
    base_step = m.make_step(ctx)
    if cohort is not None:
        cohort = int(cohort)
        axes = m.cohort_axes(ctx, states)
        if not 0 < cohort <= ctx.n_clients:
            raise ValueError(
                f"{entry}: cohort_size={cohort} must be in 1..N="
                f"{ctx.n_clients}"
            )
        _require_dynamic_graph(m, "cohort subsampling")
        # cohort stream: deterministic per (seed, round) — fold_in(r) in
        # the program keeps loop and scan on the identical cohorts
        cohort_key = jax.random.fold_in(jax.random.PRNGKey(seeds[0]),
                                        0x5EED)
        base_step = _cohort_step(base_step, axes)
        if adj_seeds is None and adj_rounds is None and adj_const is None:
            adj_const = jnp.asarray(ctx.graph.adj, jnp.float32)

    # ---- client-system heterogeneity (Scenario.system) ---------------------
    het = scenario.system if scenario is not None else None
    het_key = het_speeds = het_carry = None
    if het is not None:
        _require_dynamic_graph(m, "client-system heterogeneity")
        # the same per-field client-axis contract cohort subsampling uses
        # (and the same constraints: packed plane, dense wiring) — the
        # masked step restores inactive rows along these axes
        het_axes = m.cohort_axes(ctx, states)
        het_speeds = jnp.asarray(het.resolve_speeds(ctx.n_clients))
        # straggler/availability stream: deterministic per (model seed,
        # round) — fold_in(r) in the program keeps both engines identical
        het_key = jax.random.fold_in(jax.random.PRNGKey(int(het.seed)),
                                     0x51AC)
        het_carry = het.init_carry(ctx.n_clients)
        # wraps OUTSIDE the cohort gather: weights cover the full client
        # axis; the activity vector rides as the LAST step extra
        base_step = masked_client_step(base_step, het_axes)

    # ---- telemetry: the traced round-metrics plane -------------------------
    # the collector runs INSIDE the round program (the scan body / the
    # per-round jitted dispatch), so both engines evaluate the identical
    # traced expressions — zero extra dispatches, compile-count-neutral
    telem = cfg.telemetry
    collect = None
    if telem is not None and telem.enabled:
        bshape = (len(seeds),) if batched else ()
        comm_model0 = m.comm_model(ctx)
        tracked = (comm_model0.kind == "tracked"
                   and hasattr(states, "comm_bytes"))
        has_u = (hasattr(states, "u")
                 and getattr(states.u, "shape", ())[-2:]
                 == (ctx.n_clients, ctx.n_clusters))
        has_plane = False
        if hasattr(states, "centers"):
            try:
                plane_sd = jax.eval_shape(
                    lambda c: flatten_centers(c, batch_ndim=len(bshape)),
                    states.centers)
                has_plane = (plane_sd.shape[len(bshape):-1]
                             == (ctx.n_clusters, ctx.n_clients))
            except Exception:
                has_plane = False
        has_mask = (hasattr(states, "mask")
                    and getattr(states, "mask", None) is not None)
        collect = make_collector(
            telem, batch_shape=bshape, n_clusters=ctx.n_clusters,
            n_clients=ctx.n_clients, wire_ratio=_wire_bytes(ctx, 1.0),
            per_round_bytes=(None if tracked
                             else comm_model0.per_round_bytes),
            has_u=has_u, has_plane=has_plane, has_mask=has_mask,
        )

    # ---- normalized closures shared by both engines ------------------------
    has_adj = (adj_seeds is not None or adj_rounds is not None
               or adj_const is not None)
    extra_axes = ()
    if has_adj:
        extra_axes += (0 if adj_seeds is not None else None,)
    if cohort is not None:
        extra_axes += (None,)
    if het is not None:
        extra_axes += (None,)
    if batched:
        step0 = jax.vmap(base_step,
                         in_axes=(0, data_ax, 0, None) + extra_axes)
    else:
        step0 = base_step

    def round_call(states, train, k, lr, extra):
        return step0(states, train, k, lr, *extra)

    def round_extra(adj, r, hc):
        """This round's traced extras: in-step Bernoulli link dropout
        (key ⊕ round), the active-cohort gather indices, and the
        per-client activity weights. Returns (extras, updated
        heterogeneity carry) — the carry threads through the loop engine
        eagerly and rides the lax.scan carry under scan_rounds."""
        ex = ()
        if has_adj:
            if drop_p > 0.0:
                adj = bernoulli_drop(
                    adj, jax.random.fold_in(drop_key, r), drop_p
                )
            ex += (adj,)
        if cohort is not None:
            ex += (_cohort_indices(
                jax.random.fold_in(cohort_key, r), ctx.n_clients, cohort
            ),)
        if het is not None:
            hc, aw = het_round(het, het_speeds, hc,
                               jax.random.fold_in(het_key, r))
            ex += (aw,)
        return ex, hc

    adj_static = adj_seeds if adj_seeds is not None else adj_const
    # static-graph methods carry no adjacency extra; telemetry still
    # reports the paper topology's degree / spectral gap each round
    telem_adj = (None if collect is None or has_adj
                 else jnp.asarray(ctx.graph.adj, jnp.float32))

    def round_call_telem(states, train, k, lr, extra, hc):
        """round_call plus the telemetry collector, in the SAME traced
        program — the effective adjacency the metrics see is exactly what
        the step mixed over (post dropout, post heterogeneity weights)."""
        new, aux2 = round_call(states, train, k, lr, extra)
        adj_eff = extra[0] if has_adj else telem_adj
        aw = extra[-1] if het is not None else None
        if aw is not None:
            adj_eff = apply_client_weights(adj_eff, aw)
        tm = collect(states, new, adj_eff, weights=aw,
                     stale=hc.stale if het is not None else None)
        return new, aux2, tm

    def split_run(kr):
        if batched:
            ks = jax.vmap(jax.random.split)(kr)
            return ks[:, 0], ks[:, 1]
        kr, k = jax.random.split(kr)
        return kr, k

    if batched:
        eval_vm = jax.vmap(
            lambda state, ke, on, tr: m.evaluate(ctx, state, ke, on,
                                                 train=tr),
            in_axes=(0, 0, data_ax, data_ax),
        )
        evaluate = jax.jit(eval_vm)

    curves = [[] for _ in seeds]
    aux = None
    tapes = None   # telemetry streams, {name: (rounds, ...)} once stacked

    # ---- engine A: lax.scan-rolled whole experiment ------------------------
    if cfg.scan_rounds:
        xs = {"r": jnp.arange(rounds, dtype=jnp.int32),
              "lr": jnp.asarray(lrs)}
        if adj_rounds is not None:
            xs["adj"] = adj_rounds
        nan_acc = (jnp.full((len(seeds),), jnp.nan, jnp.float32) if batched
                   else jnp.asarray(jnp.nan, jnp.float32))

        def eval_mean(op):
            # the cond sits OUTSIDE the vmapped region (do_eval depends
            # only on the round index, shared by every seed), so skipped
            # rounds genuinely skip the eval computation
            sts, train = op
            if batched:
                return jnp.mean(eval_vm(sts, k_eval, train, train),
                                axis=-1)
            return jnp.mean(m.evaluate(ctx, sts, k_eval, train))

        def program(states, train, kr, hc, xs):
            def body(carry, x):
                sts, kr, hc = carry
                kr, k = split_run(kr)
                a = x["adj"] if adj_rounds is not None else adj_static
                ex, hc = round_extra(a, x["r"], hc)
                if collect is not None:
                    # telemetry rides the scan ys next to the acc tape —
                    # same program, zero extra dispatches
                    sts, _, tm = round_call_telem(sts, train, k, x["lr"],
                                                  ex, hc)
                else:
                    sts, _ = round_call(sts, train, k, x["lr"], ex)
                    tm = None
                do = jnp.logical_or(x["r"] % eval_every == 0,
                                    x["r"] == rounds - 1)
                acc = jax.lax.cond(do, eval_mean, lambda op: nan_acc,
                                   (sts, train))
                return (sts, kr, hc), (acc, tm)

            # hc is None (an empty pytree carry leaf) without a
            # heterogeneity model — the compiled program is unchanged
            (states, kr, hc), ys = jax.lax.scan(body, (states, kr, hc),
                                                xs)
            return states, hc, ys

        runner = jax.jit(program, donate_argnums=_donate_argnums(options))
        if not batched:
            states = jax.tree.map(lambda l: l.astype(l.dtype), states)
        states, het_carry, (accs_tape, tapes) = runner(states, train_arg,
                                                       k_run, het_carry,
                                                       xs)
        accs_tape = np.asarray(accs_tape)   # (rounds,) or (rounds, k)
        for r in range(rounds):
            if r % eval_every == 0 or r == rounds - 1:
                for i in range(len(seeds)):
                    v = accs_tape[r, i] if batched else accs_tape[r]
                    curves[i].append((r, float(v)))
        n_compiles, n_disp = compile_count(runner), 1

    # ---- engine B: the historical Python loop ------------------------------
    else:
        step_jit = jax.jit(round_call_telem if collect is not None
                           else round_call,
                           donate_argnums=_donate_argnums(options))
        n_disp = 0
        tm_rounds = []
        for r in range(rounds):
            k_run, k = split_run(k_run)
            a = adj_rounds[r] if adj_rounds is not None else adj_static
            ex, het_carry = round_extra(a, r, het_carry)
            with step_annotation("repro/round", r):
                if collect is not None:
                    states, aux, tm = step_jit(states, train_arg, k,
                                               lrs[r], ex, het_carry)
                    tm_rounds.append(tm)
                else:
                    states, aux = step_jit(states, train_arg, k, lrs[r],
                                           ex)
            n_disp += 1
            if r % eval_every == 0 or r == rounds - 1:
                if batched:
                    train_acc = evaluate(states, k_eval, train_arg,
                                         train_arg)
                    for i in range(len(seeds)):
                        curves[i].append((r, float(jnp.mean(train_acc[i]))))
                else:
                    train_acc = m.evaluate(ctx, states, k_eval, ctx.train)
                    curves[0].append((r, float(jnp.mean(train_acc))))
        n_compiles = compile_count(step_jit)
        if tm_rounds:
            tapes = jax.tree.map(lambda *xs: jnp.stack(xs), *tm_rounds)

    # ---- final test eval + per-seed results --------------------------------
    if batched:
        accs = np.asarray(evaluate(states, k_eval, test_arg, train_arg))
    else:
        accs = np.asarray(m.evaluate(ctx, states, k_eval, ctx.test))[None]
    # the straggler stream is shared across seeds (like the dropout mask),
    # so every seed reports the same final staleness counters — and, with
    # telemetry on, a run WITHOUT a system model reports the all-zeros
    # counters rather than omitting the key, identically on both engines
    if het is not None:
        het_stale = np.asarray(het_carry.stale)
    elif collect is not None:
        het_stale = np.zeros((ctx.n_clients,), np.int32)
    else:
        het_stale = None
    if tapes is not None:
        tapes = {name: np.asarray(v) for name, v in tapes.items()}
    results = []
    for i in range(len(seeds)):
        if batched:
            state_i = jax.tree.map(lambda l: l[i], states)
            aux_i = jax.tree.map(lambda l: l[i], aux) if aux else aux
        else:
            state_i, aux_i = states, aux
        telemetry_i = None
        if tapes is not None:
            telemetry_i = {"rounds": rounds, "streams": {
                name: (v[:, i] if batched else v)
                for name, v in tapes.items()}}
        results.append(
            _result(m, ctx, state_i, aux_i, accs[i], curves[i], t0,
                    n_compiles=n_compiles, n_dispatches=n_disp,
                    staleness=het_stale, telemetry=telemetry_i)
        )
    return results if batched else results[0]


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------


def run_method(
    method: str,
    data: ClientDataset,
    exp: PaperExpConfig,
    graph: Graph | None = None,
    seed: int = 0,
    cfg: RunConfig | None = None,
    *,
    eval_every=_UNSET,
    gossip_mode=_UNSET,
    gossip_backend=_UNSET,
    param_plane=_UNSET,
    comm=_UNSET,
    scenario=_UNSET,
    options=_UNSET,
) -> RunResult:
    """Run one method for ``exp.rounds`` rounds; returns RunResult.

    All execution configuration lives in ``cfg`` (experiments/config.py's
    ``RunConfig``): gossip wiring and backend, the packed (S, N, X)
    parameter plane, the wire codec, dynamic-topology scenarios, eval
    cadence, state donation, the lax.scan-rolled round engine
    (``scan_rounds=True`` — one compile and one dispatch for the whole
    experiment), and per-round client subsampling (``cohort_size``).  The
    keyword-only loose kwargs are the PRE-RunConfig API, kept as
    DeprecationWarning shims.
    """
    cfg = _coerce_cfg(cfg, dict(
        eval_every=eval_every, gossip_mode=gossip_mode,
        gossip_backend=gossip_backend, param_plane=param_plane, comm=comm,
        scenario=scenario, options=options,
    ), "run_method")
    return _drive("run_method", method, data, exp, graph, seed, cfg)


def _stack_graphs(m: Method, graph, seeds, entry: str):
    """Per-seed graphs (a sequence in ``graph``): stacked into a (k, N, N)
    traced adjacency vmapped over the seed axis; the context gets the
    union graph (static machinery must cover every seed's edges)."""
    if graph is None or isinstance(graph, Graph):
        return None, graph
    graphs = list(graph)
    if len(graphs) != len(seeds):
        raise ValueError(
            f"{entry}: per-seed graphs: got {len(graphs)} graphs for "
            f"{len(seeds)} seeds {tuple(seeds)}"
        )
    _require_dynamic_graph(m, "per-seed graphs")
    adj = np.stack([g.adj for g in graphs]).astype(np.float32)
    return jnp.asarray(adj), union_graph(adj)


def _stack_data(data, seeds, entry: str):
    """The stacked-data variant: ``data`` as a per-seed sequence of
    ClientDatasets becomes (k, N, M, ...) train/test stacks mapped over
    the seed axis (the paper's per-seed-dataset repeated-trials
    protocol). A single ClientDataset keeps the shared-data behaviour."""
    if isinstance(data, ClientDataset):
        return data, None, None
    datasets = list(data)
    if len(datasets) != len(seeds):
        raise ValueError(
            f"{entry}: stacked data: got {len(datasets)} datasets for "
            f"{len(seeds)} seeds {tuple(seeds)}"
        )
    for i, d in enumerate(datasets[1:], start=1):
        if (d.x.shape != datasets[0].x.shape
                or d.n_classes != datasets[0].n_classes
                or d.n_clusters != datasets[0].n_clusters):
            raise ValueError(
                f"{entry}: stacked datasets must share shapes/classes/"
                f"clusters (one fused XLA program runs every seed) — the "
                f"dataset at seed index {i} (seed {seeds[i]}) differs "
                f"from seed index 0"
            )
    train = {
        "inputs": jnp.asarray(np.stack([d.x for d in datasets])),
        "targets": jnp.asarray(np.stack([d.y for d in datasets])),
    }
    test = {
        "inputs": jnp.asarray(np.stack([d.x_test for d in datasets])),
        "targets": jnp.asarray(np.stack([d.y_test for d in datasets])),
    }
    return datasets[0], train, test


def run_method_batch(
    method: str,
    data,
    exp: PaperExpConfig,
    seeds=(0, 1, 2),
    graph: Graph | None = None,
    cfg: RunConfig | None = None,
    *,
    eval_every=_UNSET,
    gossip_mode=_UNSET,
    gossip_backend=_UNSET,
    param_plane=_UNSET,
    comm=_UNSET,
    scenario=_UNSET,
    options=_UNSET,
) -> list[RunResult]:
    """Multi-seed batched execution: ONE jit compile shared by all seeds.

    The per-seed state pytrees are stacked on a leading seed axis; the
    method's step runs under ``jax.vmap`` inside a single ``jax.jit``, so
    round r of every seed executes as one fused XLA program.  Returns one
    RunResult per seed.  Takes the IDENTICAL ``RunConfig`` as
    ``run_method`` — including ``scan_rounds=True`` (the vmapped round
    body rolls into the same lax.scan) — and reports
    ``extras["n_compiles"]`` identically (a single-seed batch matches
    ``run_method`` exactly).

    Three batching axes compose:

    - shared data + shared graph (the default): only the random state —
      model init, batch sampling, cluster selection — differs per seed;
    - stacked data: ``data`` as a SEQUENCE of per-seed ClientDatasets
      (or ``scenario.data_stack``) maps a (k, N, M, ...) data stack over
      the seed axis — the paper's Tables 2–3 per-seed-dataset protocol;
    - per-seed graphs: ``graph`` as a sequence stacks a (k, N, N) traced
      adjacency over the seed axis (methods with
      ``supports_dynamic_graph``; the context wiring uses the union
      graph). A dynamic ``scenario`` instead feeds one (N, N) slice of
      its (rounds, N, N) schedule per round, shared by every seed.
    """
    cfg = _coerce_cfg(cfg, dict(
        eval_every=eval_every, gossip_mode=gossip_mode,
        gossip_backend=gossip_backend, param_plane=param_plane, comm=comm,
        scenario=scenario, options=options,
    ), "run_method_batch")
    return _drive("run_method_batch", method, data, exp, graph, seeds, cfg)
