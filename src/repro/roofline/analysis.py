"""Roofline terms from a compiled dry-run artifact (no hardware required).

compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
memory term     = HLO_bytes / (chips × HBM_bw)
collective term = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` operates on the *partitioned* (per-device)
module, so its flops/bytes are per-chip; the global figures are × chips and
the two conventions cancel in the terms above. Collective bytes are NOT in
cost_analysis — we parse the optimized HLO text and sum the output operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (per-device bytes moved, one row of the
collective).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = bf16[128,4096]{1,0} all-gather(...)`  (also tuple results
# `(f32[...], f32[...]) all-reduce(...)`)
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def cost_dict(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: newer jaxlibs return one dict,
    older ones a one-element list of dicts (indexing that list with "flops"
    raised TypeError throughout the dryrun/mesh path)."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes produced by each collective family in the optimized
    HLO (done-ops of async pairs are skipped; the start op carries shape)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _INSTR_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shapes)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    step_kind: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float          # 6·N_active·D (global)
    useful_ratio: float         # MODEL_FLOPS / global HLO_FLOPs
    peak_fraction: float        # compute_s / max(term)
    memory_per_chip: Optional[dict] = None
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def dominant_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def analyze(
    *,
    arch: str,
    shape: str,
    step_kind: str,
    mesh_name: str,
    chips: int,
    compiled,
    lowered=None,
    model_flops: float = 0.0,
    note: str = "",
) -> Roofline:
    cost = cost_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll["total"] / ICI_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    bottleneck = max(terms, key=terms.get)

    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            }
    except Exception:
        pass

    global_flops = flops * chips
    dom = max(terms.values())
    return Roofline(
        arch=arch, shape=shape, step_kind=step_kind, mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops, bytes_per_chip=bytes_acc,
        coll_bytes_per_chip=float(coll["total"]), coll_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
        peak_fraction=(compute_s / dom) if dom > 0 else 0.0,
        memory_per_chip=mem, note=note,
    )


def model_flops_for(cfg, shape, step_kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode counts one
    token per request, forward-only shapes use 2·N·D."""
    from repro.models.registry import active_params

    n_active = active_params(cfg)
    if cfg.family == "audio":
        # whisper: prefill runs the ENCODER over 1500 stub frames (+ cross-KV
        # projections), decode/train run the decoder; approximate per-branch
        d_enc = cfg.encoder_d_model or cfg.d_model
        enc_p = cfg.encoder_layers * (4 * d_enc * d_enc + 2 * d_enc * cfg.d_ff)
        dec_p = n_active - enc_p
        if step_kind == "prefill":
            return 2.0 * enc_p * shape.global_batch * cfg.encoder_frames
        if step_kind == "decode":
            return 2.0 * dec_p * shape.global_batch
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * dec_p * tokens + 2.0 * enc_p * shape.global_batch * cfg.encoder_frames
    if step_kind in ("fedspd", "plain"):
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if step_kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    if step_kind == "decode":
        return 2.0 * n_active * shape.global_batch  # one token each
    raise ValueError(step_kind)


def format_table(rows: list[Roofline]) -> str:
    hdr = (
        f"{'arch':24s} {'shape':12s} {'step':8s} {'mesh':10s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'bottleneck':>10s} {'useful':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.step_kind:8s} {r.mesh:10s} "
            f"{r.compute_s:10.3e} {r.memory_s:10.3e} {r.collective_s:10.3e} "
            f"{r.bottleneck:>10s} {r.useful_ratio:7.3f}"
        )
    return "\n".join(lines)


def save_rows(rows: list[Roofline], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_json() for r in rows], f, indent=1)


# --------------------------------------------------------------------------
# Two-point trip-count correction
# --------------------------------------------------------------------------
#
# XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
# count, so a scan-over-layers program under-reports flops/bytes and any
# collectives inside the loop. The dry-run therefore compiles each case
# twice — scan_unroll=1 and scan_unroll=2 (one extra layer body per scan
# site) — and extrapolates:
#
#   exact = m1 + r · (m2 - m1),   r = (Σ_site trips - n_sites) / n_sites
#
# which is exact when all scan sites have identical per-iteration cost
# (true here: stacked-parameter layer scans; hybrid's segment scans all
# iterate the same Mamba2 block; whisper's encoder/decoder scans share a
# trip count). The attention pair scan is fully unrolled in both compiles
# (exact), and the SSD inter-chunk scan body is a negligible state
# multiply-add (counted once; error < 0.1%).


def scan_trip_ratio(cfg) -> float:
    """r for the two-point correction, derived from the arch's scan sites."""
    if cfg.family == "hybrid":
        from repro.models.hybrid import segment_sizes

        sizes = segment_sizes(cfg)
        return (sum(sizes) - len(sizes)) / len(sizes)
    if cfg.family == "audio":
        # sites: encoder scan (enc_layers) + decoder scan (n_layers)
        total = cfg.encoder_layers + cfg.n_layers
        return (total - 2) / 2
    return float(cfg.n_layers - 1)


def two_point(v1: float, v2: float, r: float) -> float:
    return max(v1, v1 + r * (v2 - v1))


def analyze_two_point(
    *,
    arch: str,
    shape: str,
    step_kind: str,
    mesh_name: str,
    chips: int,
    compiled1,
    compiled2,
    ratio: float,
    model_flops: float = 0.0,
    note: str = "",
) -> Roofline:
    c1 = cost_dict(compiled1)
    c2 = cost_dict(compiled2)
    flops = two_point(float(c1.get("flops", 0.0)),
                      float(c2.get("flops", 0.0)), ratio)
    bytes_acc = two_point(float(c1.get("bytes accessed", 0.0)),
                          float(c2.get("bytes accessed", 0.0)), ratio)
    k1 = collective_bytes(compiled1.as_text())
    k2 = collective_bytes(compiled2.as_text())
    coll = {
        k: two_point(float(k1[k]), float(k2[k]), ratio)
        for k in (*_COLLECTIVES, "total")
    }
    coll["count"] = k1["count"]

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = coll["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = None
    try:
        ma = compiled1.memory_analysis()
        if ma is not None:
            mem = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes",
                )
                if hasattr(ma, k)
            }
    except Exception:
        pass

    global_flops = flops * chips
    dom = max(terms.values())
    return Roofline(
        arch=arch, shape=shape, step_kind=step_kind, mesh=mesh_name,
        chips=chips, flops_per_chip=flops, bytes_per_chip=bytes_acc,
        coll_bytes_per_chip=float(coll["total"]), coll_breakdown=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
        peak_fraction=(compute_s / dom) if dom > 0 else 0.0,
        memory_per_chip=mem, note=note,
    )
