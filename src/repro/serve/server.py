"""ClusterPlaneServer: batched personalized inference off one hot plane.

FedSPD's product is Eq. (2)'s per-user soft mixture of S cluster models.
The naive serving shape materializes one pytree per user — dead on
arrival at the ROADMAP's millions-of-users cardinality. This server holds
the packed ``(S, X)`` cluster plane hot on device ONCE and answers a
heterogeneous request batch — ``(B, S)`` mixture weights + inputs — by
contracting the weights over the plane inside the compiled program:

  fp32   u @ plane                     (one einsum)
  int8   kernels/gossip_mix_dequant    (fused dequant + mix, int8 HBM)
  int4   kernels/mixture_mix_dequant4  (fused nibble-unpack + dequant +
                                        mix, ~0.5 byte/param HBM)

The (B, X) personalized parameters exist only as an intermediate inside
the step — unpacked through the PackSpec bridge into (B,)-leaved pytrees
and consumed by a vmapped forward/decode immediately. Each entry point is
ONE jitted program: ``n_compiles`` (via the jit cache size, same
accounting as the train engines) and ``n_dispatches`` are exposed so
tests can assert one-compile/one-dispatch-per-call.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackSpec, unpack
from repro.kernels.gossip_mix import gossip_mix_dequant, mixture_mix_dequant4
from repro.serve.artifact import ServableArtifact
from repro.telemetry import LatencyStats, compile_count


class ClusterPlaneServer:
    """Serve personalized mixtures from one resident cluster plane.

    Construct from a loaded artifact (``from_artifact``) or directly from
    a plane in one of the shipping forms. ``bundle`` (a models/registry
    ModelBundle) enables ``generate``; ``apply_fn`` (a per-model forward
    like smallnets' classifiers, taking a (1, ...) minibatch) enables
    ``predict``.
    """

    def __init__(self, spec: PackSpec, *, codec: str = "fp32",
                 qblock: int = 64, plane=None, plane_q=None,
                 plane_scale=None, plane_packed=None, u_table=None,
                 bundle=None, apply_fn=None, interpret: bool = True):
        self.spec = spec
        self.codec = codec
        self.qblock = int(qblock)
        self.interpret = interpret
        self.bundle = bundle
        self.apply_fn = apply_fn
        self.u_table = None if u_table is None else np.asarray(
            u_table, np.float32)
        x = spec.size
        if codec == "fp32":
            if plane is None:
                raise ValueError("codec='fp32' needs plane=(S, X)")
            self.plane = jnp.asarray(plane, jnp.float32)
            if self.plane.ndim != 2 or self.plane.shape[1] != x:
                raise ValueError(
                    f"plane {self.plane.shape} is not (S, X={x})")
            self.n_clusters = int(self.plane.shape[0])
        elif codec == "int8":
            if plane_q is None or plane_scale is None:
                raise ValueError("codec='int8' needs plane_q + plane_scale")
            self.plane_q = jnp.asarray(plane_q)
            self.plane_scale = jnp.asarray(plane_scale, jnp.float32)
            self.n_clusters = int(self.plane_q.shape[0])
        elif codec == "int4":
            if plane_packed is None or plane_scale is None:
                raise ValueError(
                    "codec='int4' needs plane_packed + plane_scale")
            self.plane_packed = jnp.asarray(plane_packed)
            self.plane_scale = jnp.asarray(plane_scale, jnp.float32)
            self.n_clusters = int(self.plane_packed.shape[0])
        else:
            raise ValueError(
                f"codec {codec!r} is not a plane shipping format")
        self.n_dispatches = 0
        self.dequant_calls = 0
        self.latency = LatencyStats()
        self._personalized = jax.jit(self._personalized_impl)
        self._predict = jax.jit(self._predict_impl)
        self._generate = jax.jit(
            self._generate_impl,
            static_argnames=("gen", "temperature", "max_len"),
        )

    def _timed(self, fn, batch: int):
        """Dispatch one entry-point batch and record its request latency
        (dispatch + device completion — what a caller actually waits)."""
        self.n_dispatches += 1
        if self.codec != "fp32":
            self.dequant_calls += 1
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        self.latency.record(time.perf_counter() - t0, batch=batch)
        return out

    @classmethod
    def from_artifact(cls, artifact: ServableArtifact, spec: PackSpec, *,
                      bundle=None, apply_fn=None,
                      interpret: bool = True) -> "ClusterPlaneServer":
        m = artifact.manifest
        if m.pack_digest is not None and m.pack_digest != spec.digest:
            raise ValueError(
                f"artifact pack_digest {m.pack_digest!r} != spec "
                f"{spec.digest!r} — wrong architecture for this plane"
            )
        return cls(
            spec, codec=m.codec, qblock=m.qblock or 64,
            plane=artifact.plane, plane_q=artifact.plane_q,
            plane_scale=artifact.plane_scale,
            plane_packed=artifact.plane_packed, u_table=artifact.u_table,
            bundle=bundle, apply_fn=apply_fn, interpret=interpret,
        )

    # -- the Eq. (2) contraction over the resident plane (traced) --------

    def _mix(self, u: jnp.ndarray) -> jnp.ndarray:
        """(B, S) mixture weights -> (B, X) personalized flat params."""
        # named_scope, not a profiler annotation: this runs INSIDE the
        # jitted entry points, where host-side spans cannot see
        with jax.named_scope(f"serve/mix_{self.codec}"):
            x = self.spec.size
            if self.codec == "fp32":
                return jnp.einsum("bs,sx->bx", u.astype(jnp.float32),
                                  self.plane)
            if self.codec == "int8":
                out = gossip_mix_dequant(
                    u.astype(jnp.float32), self.plane_q, self.plane_scale,
                    qblock=self.qblock, interpret=self.interpret,
                )
            else:  # int4
                out = mixture_mix_dequant4(
                    u.astype(jnp.float32), self.plane_packed,
                    self.plane_scale,
                    qblock=self.qblock, interpret=self.interpret,
                )
            return out[:, :x]

    # -- entry points (each ONE jitted program) --------------------------

    def _personalized_impl(self, u):
        return unpack(self._mix(u), self.spec)

    def personalized(self, u) -> Any:
        """(B, S) -> personalized params pytree with (B,)-leading leaves."""
        u = jnp.asarray(u)
        return self._timed(lambda: self._personalized(u), u.shape[0])

    def _predict_impl(self, u, inputs):
        params = unpack(self._mix(u), self.spec)

        def one(p, x):
            return self.apply_fn(p, x[None, ...])[0]

        return jax.vmap(one)(params, inputs)

    def predict(self, u, inputs) -> jnp.ndarray:
        """Personalized forward: request i's input through request i's
        mixture — mix, unpack, and the vmapped apply in one program."""
        if self.apply_fn is None:
            raise ValueError("predict needs apply_fn= at construction")
        u = jnp.asarray(u)
        inputs = jnp.asarray(inputs)
        return self._timed(lambda: self._predict(u, inputs), u.shape[0])

    def _generate_impl(self, u, prompts, key, *, gen, temperature, max_len):
        bundle = self.bundle
        vocab = bundle.cfg.vocab
        params = unpack(self._mix(u), self.spec)
        lp = prompts.shape[1]

        # per-request prefill: pos lands at lp statically, so the first
        # generated token always comes from re-scoring the last prompt
        # token (same contract as the old launch/serve.generate)
        def one(p, prompt):
            cache = bundle.init_cache(1, max_len)
            cache = bundle.prefill(p, {"tokens": prompt[None, :]}, cache)
            cache = dict(cache)
            cache["pos"] = jnp.asarray(lp - 1, jnp.int32)
            logits, cache = bundle.decode_step(p, cache, prompt[None, -1:])
            return logits[0, -1, :vocab], cache

        lg0, caches = jax.vmap(one)(params, prompts)

        def sample(lg, k):
            if temperature > 0:
                tok = jax.random.categorical(k, lg / temperature)
            else:
                tok = jnp.argmax(lg, axis=-1)
            return tok.astype(jnp.int32)

        def body(carry, k):
            lg, caches = carry

            def stepf(p, c, t):
                logits, c2 = bundle.decode_step(p, c, t[None, None])
                return logits[0, -1, :vocab], c2

            tok = sample(lg, k)                       # (B,)
            lg2, caches2 = jax.vmap(stepf)(params, caches, tok)
            return (lg2, caches2), tok

        keys = jax.random.split(key, gen)
        _, toks = jax.lax.scan(body, (lg0, caches), keys)
        return toks.T                                 # (B, gen)

    def generate(self, u, prompts, *, gen: int, temperature: float = 0.0,
                 key=None) -> jnp.ndarray:
        """Batched personalized generation: B requests, each with its own
        mixture row, decoded in ONE compiled program (prefill + re-score +
        lax.scan over the gen tokens). Returns (B, gen) int32 tokens."""
        if self.bundle is None:
            raise ValueError("generate needs bundle= at construction")
        prompts = jnp.asarray(prompts, jnp.int32)
        if key is None:
            key = jax.random.PRNGKey(0)
        max_len = prompts.shape[1] + int(gen) + 1
        u = jnp.asarray(u)
        return self._timed(
            lambda: self._generate(
                u, prompts, key, gen=int(gen),
                temperature=float(temperature), max_len=max_len,
            ),
            prompts.shape[0],
        )

    def serve_client(self, client: int, prompts, *, gen: int,
                     temperature: float = 0.0, key=None) -> jnp.ndarray:
        """Generate for one trained client: its u-table row broadcast over
        the request batch."""
        if self.u_table is None:
            raise ValueError("serve_client needs u_table= at construction")
        row = self.u_table[int(client)]
        u = np.broadcast_to(row, (np.shape(prompts)[0], row.shape[0]))
        return self.generate(u, prompts, gen=gen, temperature=temperature,
                             key=key)

    # -- accounting (same convention as the train engines) ---------------

    @property
    def n_compiles(self) -> int:
        """Total compiled programs across the three entry points."""
        return sum(max(0, compile_count(f)) for f in
                   (self._personalized, self._predict, self._generate))

    @property
    def plane_bytes(self) -> int:
        """Resident HBM footprint of the hot plane (weights + scales) —
        the plane-residency counter in the serve telemetry snapshot."""
        if self.codec == "fp32":
            return int(self.plane.size) * 4
        if self.codec == "int8":
            return int(self.plane_q.nbytes) + int(self.plane_scale.nbytes)
        return int(self.plane_packed.nbytes) + int(self.plane_scale.nbytes)

    def telemetry_snapshot(self) -> dict:
        """One JSON-able dict of the serve-path counters: codec, plane
        residency, compile/dispatch/dequant counts, and the per-batch
        latency percentiles + QPS (telemetry/events.py's
        ``serve_summary`` event; the summary renderer tables it)."""
        return {
            "codec": self.codec,
            "n_clusters": self.n_clusters,
            "plane_bytes": self.plane_bytes,
            "n_compiles": self.n_compiles,
            "n_dispatches": self.n_dispatches,
            "dequant_calls": self.dequant_calls,
            **self.latency.snapshot(),
        }
