"""Servable artifact: the (S, X) cluster plane in its shipping format.

A finished FedSPD run owns N·S cluster-center copies; what a server needs
is the S consensus cluster models packed as one (S, X) plane, the trained
(N, S) mixture table, and the PackSpec identity — nothing else. This
module defines that artifact:

  fp32   plane stored as the raw (S, X) float32 array
  int8   plane stored as the EXACT wire bytes of comm/codecs'
         ``serialize_payload`` — S · wire_model_bytes of int8 quanta +
         fp32 per-block scales
  int4   same, at S · wire_model_bytes = S · (ceil(X/2) + 2·nq) bytes:
         paired two's-complement nibbles in uint8 + fp16 scales

Quantized planes are encoded with ``rounding="nearest"`` — shipping is a
one-time deterministic export, not an unbiased stochastic stream — and
load back into the forms the fused kernels consume (int8 storage for
``gossip_mix_dequant``, bit-packed uint8 for ``mixture_mix_dequant4``).
The manifest pins arch / plane shape / PackSpec digest / codec so a
server cannot silently unpack a plane through the wrong layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.comm.codecs import Channel, CommConfig, int4_pack
from repro.core.packing import PackSpec


@dataclasses.dataclass
class ServableArtifact:
    """A loaded servable plane, already in serving form."""

    manifest: ckpt.CkptManifest
    u_table: Optional[np.ndarray] = None   # (N, S) trained mixtures
    plane: Optional[np.ndarray] = None     # (S, X) fp32 — codec == fp32
    plane_q: Optional[np.ndarray] = None   # (S, Xp) int8 quanta (quantized)
    plane_scale: Optional[np.ndarray] = None  # (S, Xp // qblock) fp32
    plane_packed: Optional[np.ndarray] = None  # (S, Xp // 2) uint8 — int4

    @property
    def n_clusters(self) -> int:
        return int(self.manifest.need("n_clusters").n_clusters)

    @property
    def codec(self) -> str:
        return self.manifest.codec


def save_servable(path: str, plane, spec: PackSpec, *,
                  arch: str, u=None, codec: str = "fp32",
                  qblock: int = 64, key=None) -> ckpt.CkptManifest:
    """Write the (S, X) cluster plane as a servable .npz in ``codec``
    shipping form; returns the manifest written alongside it."""
    plane = np.asarray(plane, np.float32)
    if plane.ndim != 2 or plane.shape[1] != spec.size:
        raise ValueError(
            f"plane {plane.shape} is not (S, X={spec.size}) for this spec")
    s = plane.shape[0]
    tree = {}
    if u is not None:
        u = np.asarray(u, np.float32)
        if u.ndim != 2 or u.shape[1] != s:
            raise ValueError(f"u table {u.shape} is not (N, S={s})")
        tree["u"] = u
    if codec == "fp32":
        tree["plane"] = plane
    elif codec in ("int8", "int4"):
        ch = Channel(CommConfig(codec=codec, block=qblock), spec.size)
        if key is None:
            key = jax.random.PRNGKey(0)
        enc = ch.encode(plane, key, rounding="nearest")
        wire = ch.serialize_payload(enc)
        assert len(wire) == s * ch.wire_model_bytes  # shipping-size contract
        tree["plane_wire"] = np.frombuffer(wire, dtype=np.uint8)
    else:
        raise ValueError(f"codec {codec!r} is not a plane shipping format")
    manifest = ckpt.CkptManifest(
        kind="servable", arch=arch, n_clients=None if u is None else
        int(u.shape[0]), n_clusters=s, plane_shape=tuple(plane.shape),
        pack_digest=spec.digest, codec=codec,
        qblock=qblock if codec != "fp32" else None,
    )
    ckpt.save(path, tree, manifest=manifest)
    return manifest


def load_servable(path: str,
                  spec: Optional[PackSpec] = None) -> ServableArtifact:
    """Load a servable artifact back into serving form, verifying the
    manifest (kind, plane shape, PackSpec digest) field-by-field."""
    manifest = ckpt.read_manifest(path)
    manifest.check(kind="servable")
    manifest.need("arch", "n_clusters", "plane_shape", "codec")
    s, x = manifest.plane_shape
    if spec is not None:
        manifest.need("pack_digest").check(pack_digest=spec.digest)
        if x != spec.size:
            raise ValueError(
                f"plane width {x} != PackSpec X {spec.size}")
    like = {}
    if manifest.n_clients is not None:
        like["u"] = np.zeros((manifest.n_clients, s), np.float32)
    ch = None
    if manifest.codec == "fp32":
        like["plane"] = np.zeros((s, x), np.float32)
    else:
        manifest.need("qblock")
        ch = Channel(
            CommConfig(codec=manifest.codec, block=manifest.qblock), x)
        like["plane_wire"] = np.zeros((s * ch.wire_model_bytes,), np.uint8)
    tree, _ = ckpt.restore(path, like)
    art = ServableArtifact(manifest=manifest, u_table=tree.get("u"))
    if manifest.codec == "fp32":
        art.plane = np.asarray(tree["plane"])
    else:
        enc = ch.deserialize_payload(
            np.asarray(tree["plane_wire"]).tobytes(), batch_prefix=(s,))
        art.plane_q = np.asarray(enc["q"])
        art.plane_scale = np.asarray(enc["scale"], np.float32)
        if manifest.codec == "int4":
            art.plane_packed = np.asarray(int4_pack(art.plane_q))
    return art
