"""Mixture-serving subsystem: Eq. (2) as an inference service.

ServeConfig (config.py) describes a session, ServableArtifact
(artifact.py) is the shipped plane, ClusterPlaneServer (server.py)
answers request batches off the hot plane. launch/serve.py is the CLI;
experiments/export.py produces artifacts from finished runs.
"""
from repro.serve.artifact import (  # noqa: F401
    ServableArtifact,
    load_servable,
    save_servable,
)
from repro.serve.config import SERVE_CODECS, ServeConfig  # noqa: F401
from repro.serve.server import ClusterPlaneServer  # noqa: F401
