"""ServeConfig: the one configuration object the serving stack takes.

Mirror of experiments/config.py's ``RunConfig`` discipline on the
inference side: ``launch/serve.py``'s flags and
``examples/serve_personalized.py`` are thin builders over this frozen
dataclass, and the old loose-kwarg surface (``generate(bundle, params,
...)`` / ``--ckpt --client`` restore-a-pytree serving) survives only as
DeprecationWarning shims guarded by tests/test_serve.py's AST call-site
check.

``resolve()`` validates and normalizes in one place — unknown arch,
non-positive shapes, a codec outside the plane shipping formats, or a
client/mixture conflict all fail HERE with the field named, before any
model is built or plane loaded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.configs.base import ARCH_ALIASES, get_config, get_smoke_config

#: Plane shipping formats the server can hold hot (comm/codecs wire forms).
SERVE_CODECS = ("fp32", "int8", "int4")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything about HOW a serve session executes.

    arch         model registry alias (configs.base.ARCH_ALIASES)
    smoke        smoke-shape config + ref attention (CI-runnable)
    batch        request-batch size B
    prompt_len   prompt tokens per request
    gen          tokens to generate per request
    temperature  0 = greedy, >0 = categorical sampling
    client       serve this trained client's mixture row from the
                 artifact's u table (exclusive with ``mixture``)
    mixture      explicit mixture weights: (S,) shared by the batch or
                 (B, S) per-request (exclusive with ``client``)
    codec        plane shipping format: fp32 | int8 | int4 (quantized
                 planes are mixed by the fused kernels/ paths)
    qblock       quantization block width for quantized codecs
    seed         PRNG seed (prompt synthesis + sampling)
    options      escape hatch for server knobs (e.g. interpret=False)
    """

    arch: str = "olmo-1b"
    smoke: bool = True
    batch: int = 4
    prompt_len: int = 32
    gen: int = 16
    temperature: float = 0.0
    client: Optional[int] = None
    mixture: Any = None
    codec: str = "fp32"
    qblock: int = 64
    seed: int = 0
    options: dict = dataclasses.field(default_factory=dict)

    def resolve(self) -> "ServeConfig":
        """Validate every field (naming the offender) and normalize
        ``mixture`` to a float32 ndarray; returns the resolved config."""
        if self.arch not in ARCH_ALIASES:
            raise ValueError(
                f"unknown arch {self.arch!r}; have {sorted(ARCH_ALIASES)}"
            )
        for field in ("batch", "prompt_len", "gen", "qblock"):
            v = getattr(self, field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(f"{field} must be a positive int, got {v!r}")
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.codec not in SERVE_CODECS:
            raise ValueError(
                f"codec {self.codec!r} is not a plane shipping format; "
                f"have {SERVE_CODECS}"
            )
        if self.codec == "int4" and self.qblock % 2:
            raise ValueError(
                f"int4 plane serving needs an even qblock (paired nibbles), "
                f"got {self.qblock}"
            )
        if self.client is not None and self.mixture is not None:
            raise ValueError(
                "client and mixture are exclusive: pick a trained client's "
                "u row OR supply explicit mixture weights"
            )
        if self.client is not None and (
                not isinstance(self.client, int) or self.client < 0):
            raise ValueError(
                f"client must be a non-negative int, got {self.client!r}")
        if self.arch_config().family == "audio":
            raise NotImplementedError(
                "audio serving needs a decoder prefill over the prompt "
                "tokens (encdec_prefill_cross only fills the cross-"
                "attention cache); use launch/dryrun.py's serve shapes"
            )
        mixture = self.mixture
        if mixture is not None:
            mixture = np.asarray(mixture, np.float32)
            if mixture.ndim not in (1, 2):
                raise ValueError(
                    f"mixture must be (S,) or (B, S), got shape "
                    f"{mixture.shape}"
                )
            if mixture.ndim == 2 and mixture.shape[0] != self.batch:
                raise ValueError(
                    f"mixture batch {mixture.shape[0]} != batch {self.batch}"
                )
            if np.any(mixture < 0):
                raise ValueError("mixture weights must be non-negative")
            tot = mixture.sum(axis=-1, keepdims=True)
            if np.any(tot <= 0):
                raise ValueError("each mixture row must have positive mass")
            mixture = mixture / tot
        return dataclasses.replace(self, mixture=mixture)

    def arch_config(self):
        """The ArchConfig this session serves (smoke-aware)."""
        return (get_smoke_config(self.arch) if self.smoke
                else get_config(self.arch))

    def request_mixture(self, n_clusters: int,
                        u_table: Optional[np.ndarray] = None) -> np.ndarray:
        """Materialize the (B, S) request mixture this config describes:
        an explicit ``mixture`` is broadcast/validated against S, a
        ``client`` index selects that row of the artifact's trained u
        table, and neither defaults to the uniform mixture."""
        b, s = self.batch, n_clusters
        if self.mixture is not None:
            m = np.asarray(self.mixture, np.float32)
            if m.shape[-1] != s:
                raise ValueError(
                    f"mixture has {m.shape[-1]} clusters, plane has {s}")
            return np.broadcast_to(m, (b, s)).copy() if m.ndim == 1 else m
        if self.client is not None:
            if u_table is None:
                raise ValueError(
                    "client= serving needs a u table (train with --save / "
                    "export_servable records it); pass mixture= instead"
                )
            if self.client >= u_table.shape[0]:
                raise ValueError(
                    f"client {self.client} out of range for u table with "
                    f"{u_table.shape[0]} clients"
                )
            row = np.asarray(u_table[self.client], np.float32)
            return np.broadcast_to(row, (b, s)).copy()
        return np.full((b, s), 1.0 / s, np.float32)
