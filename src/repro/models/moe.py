"""Mixture-of-Experts FFN (Switch/Mixtral-style top-k with capacity routing).

Dispatch is scatter-based (token -> (expert, slot) buffer) rather than the
dense one-hot (T, E, C) einsum: at olmoe scale (64 experts, top-8, 4k seq)
the one-hot dispatch tensor alone would be larger than the activations.
Experts are sharded over the "model" mesh axis (E dimension), so the expert
einsum is embarrassingly parallel and XLA inserts the token all-to-alls.

Aux load-balance loss (Switch-style f·P) is returned alongside the output so
the router learns a balanced assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, d_model: int, d_ff: int, n_experts: int, act: str, dtype):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    p = {
        "router": dense_init(kr, d_model, n_experts, jnp.float32),
        "w_in": (jax.random.normal(k1, (n_experts, d_model, d_ff))
                 / jnp.sqrt(d_model)).astype(dtype),
        "w_out": (jax.random.normal(k2, (n_experts, d_ff, d_model)) / jnp.sqrt(d_ff)).astype(dtype),
    }
    if act == "silu":
        p["w_gate"] = (
            jax.random.normal(k3, (n_experts, d_model, d_ff)) / jnp.sqrt(d_model)
        ).astype(dtype)
    return p


def _slot_positions_cumsum(flat_expert: jnp.ndarray, e: int) -> jnp.ndarray:
    """Naive Switch dispatch: position of each (token, slot) within its
    expert queue via a running sum over the one-hot matrix. O(T·k · E)
    memory traffic and XLA costs the cumsum as a reduce-window — the §Perf
    hillclimb measured a 73x whole-step compute-term inflation from it at
    32k-prefill scale (olmoe: 23.2 s -> 0.32 s after switching to sort)."""
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.sum(pos * onehot, axis=-1)  # (T*k,)


def _slot_positions_sort(flat_expert: jnp.ndarray, e: int) -> jnp.ndarray:
    """Identical positions via stable argsort ranking: rank within the
    expert-sorted order minus the expert segment start. O(T·k log T·k)."""
    tk = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)          # (T*k,)
    ranks = jnp.zeros((tk,), jnp.int32).at[order].set(
        jnp.arange(tk, dtype=jnp.int32))
    counts = jnp.zeros((e,), jnp.int32).at[flat_expert].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    return ranks - starts[flat_expert]


def apply_moe(
    params,
    x: jnp.ndarray,  # (B, L, D)
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    dispatch: str = "sort",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B, L, D), aux_loss ()).

    dispatch="grouped" computes capacity PER SEQUENCE (leading B axis kept
    through the dispatch buffers), so on a mesh with B data-sharded the
    scatter/gather stay shard-local — no cross-data-shard all-reduce of
    global (E, C, D) buffers (§Perf H3). Global-capacity modes: "cumsum"
    (naive running sum) and "sort" (argsort ranking)."""
    if dispatch == "grouped":
        out, aux = jax.vmap(
            lambda xr: _moe_core(
                params, xr[None], top_k=top_k,
                capacity_factor=capacity_factor, act=act, dispatch="sort",
            )
        )(x)
        return out[:, 0], jnp.mean(aux)
    return _moe_core(params, x, top_k=top_k, capacity_factor=capacity_factor,
                     act=act, dispatch=dispatch)


def _moe_core(
    params,
    x: jnp.ndarray,  # (B, L, D)
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    dispatch: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, l, d = x.shape
    e = params["w_in"].shape[0]
    t = b * l
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Switch aux loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce) / top_k

    capacity = int(max(1, capacity_factor * top_k * t / e))
    if l == 1:
        # single-token decode: the fractional capacity rounds down to ~1 and
        # silently drops later batch rows that share an expert with earlier
        # ones (prefill+decode then disagrees with the teacher-forced
        # forward). Each token occupies at most one slot per expert, so
        # capacity=t makes the decode path drop-free and exact.
        capacity = t
    capacity = min(capacity, t)

    # position of each (token, slot) within its expert queue
    flat_expert = expert_idx.reshape(-1)  # (T*k,) — slot-major order: token t, slot j -> t*k + j
    if dispatch == "cumsum":
        pos = _slot_positions_cumsum(flat_expert, e)
    else:
        pos = _slot_positions_sort(flat_expert, e)
    keep = pos < capacity
    slot = jnp.where(keep, pos, capacity - 1)

    token_of = jnp.repeat(jnp.arange(t), top_k)
    compute_dtype = x.dtype
    buf = jnp.zeros((e, capacity, d), compute_dtype)
    contrib = jnp.where(keep[:, None], xt[token_of], 0).astype(compute_dtype)
    buf = buf.at[flat_expert, slot].add(contrib)

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * h
    else:
        h = jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_out"])  # (E, C, D)

    gathered = out_buf[flat_expert, slot]  # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * gate_vals.reshape(-1)[:, None]
    if dispatch == "cumsum":
        # naive combine: data-dependent scatter-add — GSPMD replicates the
        # (T, D) accumulator and all-reduces it per layer (§Perf H3)
        out = jnp.zeros((t, d), jnp.float32).at[token_of].add(weighted)
    else:
        # token_of = repeat(arange(T), k) is contiguous groups of k: the
        # scatter is a strided segment sum -> reshape + sum, collective-free
        out = weighted.reshape(t, top_k, d).sum(axis=1)
    return out.reshape(b, l, d).astype(x.dtype), aux
