"""Shared neural-net building blocks (functional, pytree params).

Conventions:
- params are nested dicts of jnp arrays; init_* return params, apply-style
  functions take (params, inputs, cfg-ish kwargs).
- all matmuls run in ``compute_dtype`` (bf16 by default) with fp32
  accumulation where it matters (norms, softmax, losses).
- layer stacks are built with vmap-init + lax.scan-apply: every layer leaf
  carries a leading (L,) axis. This keeps HLO size O(1) in depth — essential
  for compiling 48-layer archs x 40 dry-run combinations.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Init = jax.nn.initializers


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    std = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_np(x, eps: float = 1e-5):
    """Non-parametric LayerNorm (OLMo): no scale, no bias [arXiv:2402.00838]."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(kind: str, d: int, dtype):
    """Returns (init_params_or_None, apply)."""
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype), lambda p, x: rmsnorm(p, x)
    if kind == "layernorm_np":
        return {}, lambda p, x: layernorm_np(x)
    raise ValueError(f"unknown norm {kind!r}")


def apply_norm(kind: str, params, x):
    if kind == "rmsnorm":
        return rmsnorm(params, x)
    if kind == "layernorm_np":
        return layernorm_np(x)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., L, H, hd); positions: broadcastable to (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,L,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP blocks
# --------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
    }
    if act == "silu":  # swiglu: gate projection
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def apply_mlp(params, x, act: str):
    h = x @ params["w_in"]
    if act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ params["w_out"]


# --------------------------------------------------------------------------
# Stacked-layer helpers (vmap init, scan apply)
# --------------------------------------------------------------------------


def stack_init(init_one: Callable, key, n_layers: int):
    """vmap a per-layer initializer over layer keys -> stacked params."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def scan_layers(apply_one: Callable, stacked_params, x, *carry_free_args):
    """Run x through L stacked layers with lax.scan.

    ``apply_one(layer_params, x, *args) -> x``; layers must be homogeneous.
    """

    def body(h, layer_params):
        return apply_one(layer_params, h, *carry_free_args), None

    out, _ = jax.lax.scan(body, x, stacked_params)
    return out


def scan_layers_with_cache(apply_one: Callable, stacked_params, x, cache, *args):
    """Like scan_layers but threads a per-layer cache pytree (leading L axis)
    through the scan and returns the updated stack."""

    def body(h, inputs):
        layer_params, layer_cache = inputs
        h, new_cache = apply_one(layer_params, h, layer_cache, *args)
        return h, new_cache

    out, new_caches = jax.lax.scan(body, x, (stacked_params, cache))
    return out, new_caches


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-position cross entropy, fp32. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE per sequence. logits (B, L, V), tokens (B, L)."""
    per_pos = softmax_xent(logits[:, :-1], tokens[:, 1:])
    return jnp.mean(per_pos, axis=-1)


def cast_params_for_compute(params: dict, compute, *, skip=("embed",)) -> dict:
    """Cast float params to the compute dtype at the forward boundary
    (MaxText-style: fp32 master store, bf16 compute). ``skip`` keys (embed
    tables) are cast after lookup instead — casting a (V, D) table would
    materialize a second copy."""
    import jax as _jax

    def cast(x):
        return x.astype(compute) if jnp.issubdtype(x.dtype, jnp.floating) else x

    return {
        k: (v if k in skip else _jax.tree.map(cast, v))
        for k, v in params.items()
    }


def unroll_arg(v: int):
    """ArchConfig unroll field -> lax.scan unroll argument (0 = full)."""
    return True if v == 0 else v
