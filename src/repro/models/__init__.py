from repro.models.registry import (  # noqa: F401
    ModelBundle,
    active_params,
    build_model,
    count_params,
)
