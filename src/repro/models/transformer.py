"""Decoder-only transformer (dense / MoE / VLM-backbone) with GQA, RoPE,
sliding-window and local:global attention patterns, scan-over-layers, and a
KV-cache decode path.

One implementation covers olmo-1b, olmoe-1b-7b, phi3.5-moe, h2o-danube,
gemma3-1b, granite-3-8b and chameleon-34b (the VLM backbone consumes VQ
image tokens through the same vocab — the codec frontend is stubbed per the
brief). Heterogeneous per-layer windows (gemma3's 5:1 local:global) ride
through the homogeneous scan as a traced per-layer window array.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.attention import attention, decode_attention
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    apply_rope,
    cast_params_for_compute,
    dense_init,
    embed_init,
    init_mlp,
    next_token_loss,
    rmsnorm_init,
    stack_init,
    unroll_arg,
)
from repro.models.moe import apply_moe, init_moe


def _norm_params(cfg: ArchConfig, dtype):
    return rmsnorm_init(cfg.d_model, dtype) if cfg.norm == "rmsnorm" else {}


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer window sizes; -1 = full causal attention.

    gemma3: repeating pattern of ``local_global_ratio`` local layers
    (window=local_window) followed by one global layer.
    """
    if cfg.local_global_ratio > 0:
        pat = [cfg.local_window] * cfg.local_global_ratio + [-1]
        w = [pat[i % len(pat)] for i in range(cfg.n_layers)]
        return np.array(w, dtype=np.int32)
    if cfg.window is not None:
        return np.full(cfg.n_layers, cfg.window, dtype=np.int32)
    return np.full(cfg.n_layers, -1, dtype=np.int32)


def static_window(cfg: ArchConfig) -> Optional[int]:
    """A single static window if all layers share one (enables block pruning)."""
    w = layer_windows(cfg)
    if (w == w[0]).all():
        return None if w[0] < 0 else int(w[0])
    return None


def init_layer(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    ks = jax.random.split(key, 8)
    hd = cfg.head_dim
    p = {
        "ln1": _norm_params(cfg, dtype),
        "ln2": _norm_params(cfg, dtype),
        "attn": {
            "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
            "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
            "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
            "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
        },
    }
    if cfg.qk_norm:
        p["attn"]["q_norm"] = rmsnorm_init(hd, dtype)
        p["attn"]["k_norm"] = rmsnorm_init(hd, dtype)
    if cfg.n_experts > 0:
        p["moe"] = init_moe(ks[4], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.act, dtype)
    else:
        p["mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def init_transformer(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    params = {
        "embed": embed_init(k_embed, cfg.vocab_padded, cfg.d_model, dtype),
        "layers": stack_init(lambda k: init_layer(k, cfg), k_layers, cfg.n_layers),
        "ln_f": _norm_params(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype)
    return params


def _project_qkv(p_attn, h, cfg: ArchConfig):
    b, l, _ = h.shape
    hd = cfg.head_dim
    q = (h @ p_attn["wq"]).reshape(b, l, cfg.n_heads, hd)
    k = (h @ p_attn["wk"]).reshape(b, l, cfg.n_kv_heads, hd)
    v = (h @ p_attn["wv"]).reshape(b, l, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = apply_norm("rmsnorm", p_attn["q_norm"], q)
        k = apply_norm("rmsnorm", p_attn["k_norm"], k)
    return q, k, v


def apply_layer(
    p, h, *, cfg: ArchConfig, positions, mode: str, window_st, dyn_window
):
    """Full-sequence layer. Returns (h, (k, v), aux)."""
    x = apply_norm(cfg.norm, p["ln1"], h)
    q, k, v = _project_qkv(p["attn"], x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    attn_out = attention(
        q, k, v, mode=mode, causal=True, window=window_st,
        dyn_window=dyn_window, unroll=unroll_arg(cfg.attn_unroll),
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
    )
    b, l, _, _ = attn_out.shape
    h = h + attn_out.reshape(b, l, -1) @ p["attn"]["wo"]

    x2 = apply_norm(cfg.norm, p["ln2"], h)
    if cfg.n_experts > 0:
        ffn_out, aux = apply_moe(
            p["moe"], x2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            act=cfg.act, dispatch=cfg.moe_dispatch,
        )
    else:
        ffn_out, aux = apply_mlp(p["mlp"], x2, cfg.act), jnp.zeros((), jnp.float32)
    return h + ffn_out, (k, v), aux


def forward(
    params,
    tokens: jnp.ndarray,  # (B, L) int32
    cfg: ArchConfig,
    *,
    attn_mode: str = "blocked",
    remat: bool = False,
    return_cache: bool = False,
):
    """Full forward. Returns (logits, aux, cache_or_None).

    cache leaves carry a leading (n_layers,) axis: k/v (L_layers, B, L, Hkv, hd).
    """
    compute = cfg.compute_dtype_jnp()
    b, l = tokens.shape
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    positions = jnp.arange(l)
    windows = jnp.asarray(layer_windows(cfg))
    w_st = static_window(cfg)
    hetero = (cfg.local_global_ratio > 0)

    def body(carry, xs):
        h, aux_sum = carry
        layer_p, w = xs
        dyn_w = jnp.where(w < 0, jnp.int32(2**30), w) if hetero else None
        fn = functools.partial(
            apply_layer, cfg=cfg, positions=positions, mode=attn_mode,
            window_st=w_st, dyn_window=dyn_w,
        )
        if remat:
            fn = jax.checkpoint(fn)
        h, kv, aux = fn(layer_p, h)
        return (h, aux_sum + aux), (kv if return_cache else None)

    (h, aux), caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (params["layers"], windows),
        unroll=unroll_arg(cfg.scan_unroll),
    )
    h = apply_norm(cfg.norm, params["ln_f"], h)
    logits = h @ (
        params["embed"].T.astype(compute)
        if cfg.tie_embeddings
        else params["head"]
    )
    if return_cache:
        k_stack, v_stack = caches
        cache = {
            "k": k_stack,  # (L_layers, B, L, Hkv, hd)
            "v": v_stack,
            "pos": jnp.asarray(l, jnp.int32),
        }
        return logits, aux, cache
    return logits, aux, None


# --------------------------------------------------------------------------
# Decode path
# --------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype_jnp()
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_layer(p, h, layer_cache, *, cfg: ArchConfig, cur_pos, window_st, dyn_window):
    """One-token layer step. layer_cache: dict(k=(B, Lc, Hkv, hd), v=...)."""
    x = apply_norm(cfg.norm, p["ln1"], h)
    q, k, v = _project_qkv(p["attn"], x, cfg)  # (B, 1, H, hd)
    pos = cur_pos[None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(
        layer_cache["k"], k.astype(layer_cache["k"].dtype), cur_pos, axis=1
    )
    vc = jax.lax.dynamic_update_slice_in_dim(
        layer_cache["v"], v.astype(layer_cache["v"].dtype), cur_pos, axis=1
    )
    attn_out = decode_attention(
        q, kc, vc, cur_pos, window=window_st, dyn_window=dyn_window
    )
    b = attn_out.shape[0]
    h = h + attn_out.reshape(b, 1, -1) @ p["attn"]["wo"]
    x2 = apply_norm(cfg.norm, p["ln2"], h)
    if cfg.n_experts > 0:
        ffn_out, _ = apply_moe(
            p["moe"], x2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            act=cfg.act, dispatch=cfg.moe_dispatch,
        )
    else:
        ffn_out = apply_mlp(p["mlp"], x2, cfg.act)
    return h + ffn_out, {"k": kc, "v": vc}


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ArchConfig):
    """tokens: (B, 1). Returns (logits (B, 1, V), new_cache)."""
    compute = cfg.compute_dtype_jnp()
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    cur_pos = cache["pos"]
    windows = jnp.asarray(layer_windows(cfg))
    w_st = static_window(cfg)
    hetero = cfg.local_global_ratio > 0

    def body(h, xs):
        layer_p, layer_cache, w = xs
        dyn_w = jnp.where(w < 0, jnp.int32(2**30), w) if hetero else None
        h, new_c = decode_layer(
            layer_p, h, layer_cache, cfg=cfg, cur_pos=cur_pos,
            window_st=w_st, dyn_window=dyn_w,
        )
        return h, new_c

    h, new_kv = jax.lax.scan(
        body, h, (params["layers"], {"k": cache["k"], "v": cache["v"]}, windows),
        unroll=unroll_arg(cfg.scan_unroll),
    )
    h = apply_norm(cfg.norm, params["ln_f"], h)
    logits = h @ (
        params["embed"].T.astype(compute) if cfg.tie_embeddings else params["head"]
    )
    new_cache = {"k": new_kv["k"], "v": new_kv["v"], "pos": cur_pos + 1}
    return logits, new_cache


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def _mask_pad_vocab(logits, cfg: ArchConfig):
    if cfg.vocab_padded == cfg.vocab:
        return logits
    neg = jnp.full((cfg.vocab_padded - cfg.vocab,), -1e30, logits.dtype)
    bias = jnp.concatenate([jnp.zeros((cfg.vocab,), logits.dtype), neg])
    return logits + bias


def lm_loss(params, batch, cfg: ArchConfig, *, attn_mode="blocked", remat=False,
            aux_weight: float = 0.01):
    logits, aux, _ = forward(
        params, batch["tokens"], cfg, attn_mode=attn_mode, remat=remat
    )
    logits = _mask_pad_vocab(logits, cfg)
    per_seq = next_token_loss(logits, batch["tokens"])
    return jnp.mean(per_seq) + aux_weight * aux


def lm_per_example_loss(params, batch, cfg: ArchConfig, *, attn_mode="blocked"):
    logits, _, _ = forward(params, batch["tokens"], cfg, attn_mode=attn_mode)
    logits = _mask_pad_vocab(logits, cfg)
    return next_token_loss(logits, batch["tokens"])  # (B,)
