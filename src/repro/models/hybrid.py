"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

Zamba2 [arXiv:2411.15242] interleaves Mamba2 layers with a single
shared-weight attention(+MLP) block invoked at regular depth intervals —
attention quality at a fraction of the parameter cost. We scan the Mamba2
segments (stacked params) and call the shared block between segments; the
shared block's weights are one set reused at every invocation, but each
invocation keeps its own KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.models.attention import decode_attention
from repro.models.layers import (
    apply_norm,
    cast_params_for_compute,
    dense_init,
    embed_init,
    rmsnorm_init,
    stack_init,
    unroll_arg,
)
from repro.models.ssm import (
    apply_mamba_layer,
    decode_mamba_layer,
    init_mamba_cache,
    init_mamba_layer,
)


def segment_sizes(cfg: ArchConfig) -> list[int]:
    """Mamba-layer counts between shared-attention invocations."""
    k = cfg.attn_every
    n = cfg.n_layers
    sizes = [k] * (n // k)
    if n % k:
        sizes.append(n % k)
    return sizes


def n_attn_invocations(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init_hybrid(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": embed_init(k1, cfg.vocab_padded, cfg.d_model, dtype),
        "mamba": stack_init(lambda k: init_mamba_layer(k, cfg), k2, cfg.n_layers),
        "shared": tfm.init_layer(k3, cfg),  # one attention+MLP block, reused
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(k4, cfg.d_model, cfg.vocab_padded, dtype),
    }


def _slice_stack(stacked, lo: int, hi: int):
    return jax.tree.map(lambda x: x[lo:hi], stacked)


def hybrid_forward(params, tokens, cfg: ArchConfig, *, attn_mode="blocked",
                   remat: bool = False):
    compute = cfg.compute_dtype_jnp()
    b, l = tokens.shape
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    positions = jnp.arange(l)
    sizes = segment_sizes(cfg)
    n_inv = n_attn_invocations(cfg)

    def mamba_body(h, layer_p):
        fn = lambda p_, h_: apply_mamba_layer(p_, h_, cfg=cfg)  # noqa: E731
        if remat:
            fn = jax.checkpoint(fn)
        return fn(layer_p, h), None

    lo = 0
    inv = 0
    for size in sizes:
        seg = _slice_stack(params["mamba"], lo, lo + size)
        h, _ = jax.lax.scan(mamba_body, h, seg,
                            unroll=unroll_arg(cfg.scan_unroll))
        lo += size
        if inv < n_inv and lo == (inv + 1) * cfg.attn_every:
            attn_fn = lambda p_, h_: tfm.apply_layer(  # noqa: E731
                p_, h_, cfg=cfg, positions=positions, mode=attn_mode,
                window_st=cfg.window, dyn_window=None,
            )[0]
            if remat:
                attn_fn = jax.checkpoint(attn_fn)
            h = attn_fn(params["shared"], h)
            inv += 1
    h = apply_norm("rmsnorm", params["ln_f"], h)
    logits = h @ params["head"]
    return logits, jnp.zeros((), jnp.float32), None


def hybrid_prefill(params, tokens, cfg: ArchConfig, cache, *,
                   attn_mode="blocked"):
    """Run the prompt through the hybrid stack capturing per-layer SSD
    states, conv tails, and shared-attention K/V at each invocation."""
    compute = cfg.compute_dtype_jnp()
    b, l = tokens.shape
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    positions = jnp.arange(l)
    sizes = segment_sizes(cfg)
    n_inv = n_attn_invocations(cfg)

    def mamba_body(h, layer_p):
        h, st = apply_mamba_layer(layer_p, h, cfg=cfg, return_state=True)
        return h, st

    ssm_states, conv_states, ks, vs = [], [], [], []
    lo = 0
    inv = 0
    for size in sizes:
        seg = _slice_stack(params["mamba"], lo, lo + size)
        h, st = jax.lax.scan(mamba_body, h, seg,
                             unroll=unroll_arg(cfg.scan_unroll))
        ssm_states.append(st["ssm"])
        conv_states.append(st["conv"])
        lo += size
        if inv < n_inv and lo == (inv + 1) * cfg.attn_every:
            h, (k, v), _ = tfm.apply_layer(
                params["shared"], h, cfg=cfg, positions=positions,
                mode=attn_mode, window_st=cfg.window, dyn_window=None,
            )
            ks.append(k)
            vs.append(v)
            inv += 1

    max_len = cache["attn_k"].shape[2]
    pad = max_len - l
    k_stack = jnp.pad(jnp.stack(ks, 0), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_stack = jnp.pad(jnp.stack(vs, 0), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return {
        "ssm": jnp.concatenate(ssm_states, 0).astype(cache["ssm"].dtype),
        "conv": jnp.concatenate(conv_states, 0).astype(cache["conv"].dtype),
        "attn_k": k_stack.astype(cache["attn_k"].dtype),
        "attn_v": v_stack.astype(cache["attn_v"].dtype),
        "pos": jnp.asarray(l, jnp.int32),
    }


def hybrid_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype_jnp()
    n_inv = n_attn_invocations(cfg)
    cache = init_mamba_cache(cfg, cfg.n_layers, batch)
    cache["attn_k"] = jnp.zeros(
        (n_inv, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype
    )
    cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def hybrid_decode_step(params, cache, tokens, cfg: ArchConfig):
    compute = cfg.compute_dtype_jnp()
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    cur_pos = cache["pos"]
    sizes = segment_sizes(cfg)
    n_inv = n_attn_invocations(cfg)

    def mamba_body(h, xs):
        layer_p, layer_cache = xs
        h, new_c = decode_mamba_layer(layer_p, h, layer_cache, cfg=cfg)
        return h, new_c

    new_ssm = []
    new_conv = []
    new_k = []
    new_v = []
    lo = 0
    inv = 0
    for size in sizes:
        seg_p = _slice_stack(params["mamba"], lo, lo + size)
        seg_c = {
            "ssm": cache["ssm"][lo : lo + size],
            "conv": cache["conv"][lo : lo + size],
        }
        h, upd = jax.lax.scan(mamba_body, h, (seg_p, seg_c),
                              unroll=unroll_arg(cfg.scan_unroll))
        new_ssm.append(upd["ssm"])
        new_conv.append(upd["conv"])
        lo += size
        if inv < n_inv and lo == (inv + 1) * cfg.attn_every:
            h, kc, vc = _shared_attn_decode(
                params["shared"], h, cache["attn_k"][inv], cache["attn_v"][inv],
                cur_pos, cfg,
            )
            new_k.append(kc)
            new_v.append(vc)
            inv += 1

    h = apply_norm("rmsnorm", params["ln_f"], h)
    logits = h @ params["head"]
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
        "attn_k": jnp.stack(new_k, axis=0) if new_k else cache["attn_k"],
        "attn_v": jnp.stack(new_v, axis=0) if new_v else cache["attn_v"],
        "pos": cur_pos + 1,
    }
    return logits, new_cache


def _shared_attn_decode(p, h, k_cache, v_cache, cur_pos, cfg: ArchConfig):
    x = apply_norm(cfg.norm, p["ln1"], h)
    q, k, v = tfm._project_qkv(p["attn"], x, cfg)
    pos = cur_pos[None]
    from repro.models.layers import apply_rope

    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), cur_pos, axis=1
    )
    vc = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), cur_pos, axis=1
    )
    attn_out = decode_attention(q, kc, vc, cur_pos, window=cfg.window)
    b = attn_out.shape[0]
    h = h + attn_out.reshape(b, 1, -1) @ p["attn"]["wo"]
    x2 = apply_norm(cfg.norm, p["ln2"], h)
    from repro.models.layers import apply_mlp

    return h + apply_mlp(p["mlp"], x2, cfg.act), kc, vc
