"""Paper-scale client models (the FedSPD paper uses small CNN/MLP models on
MNIST/CIFAR; our offline analogue datasets are vector-valued, so the faithful
counterpart is an MLP — plus a tiny 1D-conv net mirroring the paper's CNN
structure for the "more complex model" ablations)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, softmax_xent


def init_mlp_classifier(key, dim: int, n_classes: int, hidden: tuple = (128, 64)):
    sizes = (dim,) + hidden + (n_classes,)
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        f"layer{i}": {
            "w": dense_init(keys[i], sizes[i], sizes[i + 1], jnp.float32),
            "b": jnp.zeros((sizes[i + 1],), jnp.float32),
        }
        for i in range(len(sizes) - 1)
    }


def apply_mlp_classifier(params, x):
    n = len(params)
    h = x
    for i in range(n):
        p = params[f"layer{i}"]
        h = h @ p["w"] + p["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def init_conv1d_classifier(key, dim: int, n_classes: int, channels: int = 16):
    """Tiny conv net: treat the feature vector as a 1-D signal; two conv
    stages + pooling + fc — the structural analogue of the paper's 2-conv
    CNN (Appendix B.1.1)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "conv1": (jax.random.normal(k1, (5, 1, channels)) * 0.2),
        "conv2": (jax.random.normal(k2, (5, channels, channels)) * 0.2),
        "fc1": {
            "w": dense_init(k3, (dim // 4) * channels, 50, jnp.float32),
            "b": jnp.zeros((50,), jnp.float32),
        },
        "fc2": {
            "w": dense_init(k4, 50, n_classes, jnp.float32),
            "b": jnp.zeros((n_classes,), jnp.float32),
        },
    }


def apply_conv1d_classifier(params, x):
    b, d = x.shape
    h = x[:, :, None]  # (B, D, 1)
    for name in ("conv1", "conv2"):
        h = jax.lax.conv_general_dilated(
            h, params[name], window_strides=(1,), padding="SAME",
            dimension_numbers=("NWC", "WIO", "NWC"),
        )
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 1), (1, 2, 1), "VALID"
        )
    h = h.reshape(b, -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def init_linear_classifier(key, dim: int, n_classes: int):
    k1, _ = jax.random.split(key)
    return {
        "w": (jax.random.normal(k1, (dim, n_classes)) / jnp.sqrt(dim)),
        "b": jnp.zeros((n_classes,)),
    }


def apply_linear_classifier(params, x):
    return x @ params["w"] + params["b"]


def make_classifier(kind: str, key, dim: int, n_classes: int):
    """Returns (params, apply, loss, per_example_loss, accuracy)."""
    if kind == "mlp":
        params = init_mlp_classifier(key, dim, n_classes)
        apply = apply_mlp_classifier
    elif kind == "linear":
        params = init_linear_classifier(key, dim, n_classes)
        apply = apply_linear_classifier
    elif kind == "conv":
        params = init_conv1d_classifier(key, dim, n_classes)
        apply = apply_conv1d_classifier
    else:
        raise ValueError(kind)

    def per_example_loss(p, batch):
        logits = apply(p, batch["x"])
        return softmax_xent(logits, batch["y"])

    def loss(p, batch):
        return jnp.mean(per_example_loss(p, batch))

    def accuracy(p, batch):
        logits = apply(p, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

    return params, apply, loss, per_example_loss, accuracy
