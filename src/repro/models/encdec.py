"""Whisper-style encoder-decoder transformer backbone [arXiv:2212.04356].

Per the brief, the audio frontend (mel spectrogram + conv feature extractor)
is a STUB: ``input_specs()`` supplies precomputed frame embeddings
(B, encoder_frames, encoder_d_model). We implement the transformer backbone:
bidirectional encoder, causal decoder with cross-attention, sinusoidal
positions (parameter-free — sidesteps learned-table sizing for the assigned
decode shapes, noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.attention import attention, decode_attention
from repro.models.layers import (
    cast_params_for_compute,
    unroll_arg,
    apply_mlp,
    apply_norm,
    dense_init,
    embed_init,
    init_mlp,
    rmsnorm_init,
    stack_init,
)


def sinusoidal_positions(length: int, dim: int, offset=0) -> jnp.ndarray:
    pos = jnp.arange(length)[:, None] + offset
    div = jnp.exp(jnp.arange(0, dim, 2) * (-np.log(10000.0) / dim))
    pe = jnp.zeros((length, dim))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _init_attn(key, cfg: ArchConfig, d_model: int):
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    dtype = cfg.param_dtype_jnp()
    return {
        "wq": dense_init(ks[0], d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d_model, dtype),
    }


def init_encoder_layer(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    k1, k2 = jax.random.split(key)
    d = cfg.encoder_d_model or cfg.d_model
    return {
        "ln1": rmsnorm_init(d, dtype),
        "ln2": rmsnorm_init(d, dtype),
        "attn": _init_attn(k1, cfg, d),
        "mlp": init_mlp(k2, d, cfg.d_ff, cfg.act, dtype),
    }


def init_decoder_layer(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": rmsnorm_init(d, dtype),
        "ln_x": rmsnorm_init(d, dtype),
        "ln2": rmsnorm_init(d, dtype),
        "attn": _init_attn(k1, cfg, d),
        "xattn": _init_attn(k2, cfg, d),
        "mlp": init_mlp(k3, d, cfg.d_ff, cfg.act, dtype),
    }


def init_encdec(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    ks = jax.random.split(key, 5)
    d_enc = cfg.encoder_d_model or cfg.d_model
    params = {
        "enc_layers": stack_init(
            lambda k: init_encoder_layer(k, cfg), ks[0], cfg.encoder_layers
        ),
        "enc_ln": rmsnorm_init(d_enc, dtype),
        "embed": embed_init(ks[1], cfg.vocab_padded, cfg.d_model, dtype),
        "dec_layers": stack_init(
            lambda k: init_decoder_layer(k, cfg), ks[2], cfg.n_layers
        ),
        "dec_ln": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab_padded, dtype),
    }
    if d_enc != cfg.d_model:
        params["enc_proj"] = dense_init(ks[4], d_enc, cfg.d_model, dtype)
    return params


def _mha(p, x_q, x_kv, cfg: ArchConfig, *, causal: bool, mode: str):
    b, lq, _ = x_q.shape
    hd = cfg.head_dim
    q = (x_q @ p["wq"]).reshape(b, lq, cfg.n_heads, hd)
    k = (x_kv @ p["wk"]).reshape(b, x_kv.shape[1], cfg.n_kv_heads, hd)
    v = (x_kv @ p["wv"]).reshape(b, x_kv.shape[1], cfg.n_kv_heads, hd)
    out = attention(q, k, v, mode=mode, causal=causal,
                    unroll=unroll_arg(cfg.attn_unroll),
                    q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    return out.reshape(b, lq, -1) @ p["wo"]


def encode(params, frames: jnp.ndarray, cfg: ArchConfig, *, attn_mode="blocked"):
    """frames: (B, T, encoder_d_model) stub embeddings."""
    compute = cfg.compute_dtype_jnp()
    params = cast_params_for_compute(params, compute)
    h = frames.astype(compute)
    h = h + sinusoidal_positions(h.shape[1], h.shape[2]).astype(compute)

    def body(h, layer_p):
        x = apply_norm("rmsnorm", layer_p["ln1"], h)
        h = h + _mha(layer_p["attn"], x, x, cfg, causal=False, mode=attn_mode)
        x2 = apply_norm("rmsnorm", layer_p["ln2"], h)
        return h + apply_mlp(layer_p["mlp"], x2, cfg.act), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=unroll_arg(cfg.scan_unroll))
    h = apply_norm("rmsnorm", params["enc_ln"], h)
    if "enc_proj" in params:
        h = h @ params["enc_proj"]
    return h  # (B, T, d_model)


def encdec_forward(params, batch_tokens, cfg: ArchConfig, *, frames=None,
                   attn_mode="blocked", remat: bool = False):
    """Teacher-forced decode over target tokens. Returns (logits, aux, None)."""
    compute = cfg.compute_dtype_jnp()
    enc = encode(params, frames, cfg, attn_mode=attn_mode)
    b, l = batch_tokens.shape
    h = params["embed"][batch_tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    h = h + sinusoidal_positions(l, cfg.d_model).astype(compute)

    def body(h, layer_p):
        def blk(lp, hh):
            x = apply_norm("rmsnorm", lp["ln1"], hh)
            hh = hh + _mha(lp["attn"], x, x, cfg, causal=True, mode=attn_mode)
            xx = apply_norm("rmsnorm", lp["ln_x"], hh)
            hh = hh + _mha(lp["xattn"], xx, enc, cfg, causal=False, mode=attn_mode)
            x2 = apply_norm("rmsnorm", lp["ln2"], hh)
            return hh + apply_mlp(lp["mlp"], x2, cfg.act)

        fn = jax.checkpoint(blk) if remat else blk
        return fn(layer_p, h), None

    h, _ = jax.lax.scan(body, h, params["dec_layers"],
                        unroll=unroll_arg(cfg.scan_unroll))
    h = apply_norm("rmsnorm", params["dec_ln"], h)
    return h @ params["head"], jnp.zeros((), jnp.float32), None


def encdec_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype_jnp()
    hd = cfg.head_dim
    t = cfg.encoder_frames
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        # cross-attention K/V precomputed from the encoder at prefill time
        "xk": jnp.zeros((cfg.n_layers, batch, t, cfg.n_kv_heads, hd), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, t, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def encdec_prefill_cross(params, frames, cfg: ArchConfig, cache, attn_mode="blocked"):
    """Fill the cross-attention K/V from encoder output."""
    enc = encode(params, frames, cfg, attn_mode=attn_mode)
    params = cast_params_for_compute(params, cfg.compute_dtype_jnp())
    b, t, _ = enc.shape
    hd = cfg.head_dim

    def body(_, layer_p):
        xk = (enc @ layer_p["xattn"]["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
        xv = (enc @ layer_p["xattn"]["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
        return None, (xk, xv)

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_layers"],
                               unroll=unroll_arg(cfg.scan_unroll))
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


def encdec_decode_step(params, cache, tokens, cfg: ArchConfig):
    compute = cfg.compute_dtype_jnp()
    b = tokens.shape[0]
    cur_pos = cache["pos"]
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)
    h = h + sinusoidal_positions(1, cfg.d_model, offset=cur_pos).astype(compute)
    hd = cfg.head_dim
    t = cfg.encoder_frames

    def body(h, xs):
        layer_p, kc, vc, xk, xv = xs
        x = apply_norm("rmsnorm", layer_p["ln1"], h)
        q = (x @ layer_p["attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
        k = (x @ layer_p["attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (x @ layer_p["attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cur_pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cur_pos, 1)
        a = decode_attention(q, kc, vc, cur_pos)
        h = h + a.reshape(b, 1, -1) @ layer_p["attn"]["wo"]
        # cross-attention against precomputed encoder K/V (all positions valid)
        xx = apply_norm("rmsnorm", layer_p["ln_x"], h)
        qx = (xx @ layer_p["xattn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
        ax = decode_attention(qx, xk, xv, jnp.asarray(t - 1, jnp.int32))
        h = h + ax.reshape(b, 1, -1) @ layer_p["xattn"]["wo"]
        x2 = apply_norm("rmsnorm", layer_p["ln2"], h)
        return h + apply_mlp(layer_p["mlp"], x2, cfg.act), (kc, vc)

    h, (new_k, new_v) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]),
        unroll=unroll_arg(cfg.scan_unroll),
    )
    h = apply_norm("rmsnorm", params["dec_ln"], h)
    logits = h @ params["head"]
    return logits, {**cache, "k": new_k, "v": new_v, "pos": cur_pos + 1}
