"""Uniform model bundle: one construction point for all assigned archs.

The FL layer (core/, baselines/) treats models as opaque pytrees + loss
callables; the launch layer needs init/forward/decode with fixed signatures.
This registry adapts every family to:

    init(key) -> params
    loss(params, batch) -> scalar                  (training objective)
    per_example_loss(params, batch) -> (B,)        (FedSPD clustering step)
    forward(params, batch) -> (logits, aux)        (prefill/eval)
    init_cache(batch, max_len) -> cache
    prefill(params, batch, cache) -> cache         (fills KV / cross-KV)
    decode_step(params, cache, tokens) -> (logits, cache)

batch: {"tokens": (B, L)} (+ {"frames": (B, T, D)} for audio).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, ssm, transformer as tfm
from repro.models.layers import next_token_loss


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    per_example_loss: Callable
    forward: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable


def _masked_next_token_loss(logits, tokens, cfg):
    logits = tfm._mask_pad_vocab(logits, cfg)
    return next_token_loss(logits, tokens)


def build_model(
    cfg: ArchConfig, *, attn_mode: str = "blocked", remat: bool = False
) -> ModelBundle:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def init(key):
            return tfm.init_transformer(key, cfg)

        def forward(params, batch):
            logits, aux, _ = tfm.forward(
                params, batch["tokens"], cfg, attn_mode=attn_mode, remat=remat
            )
            return logits, aux

        def loss(params, batch):
            logits, aux = forward(params, batch)
            per_seq = _masked_next_token_loss(logits, batch["tokens"], cfg)
            return jnp.mean(per_seq) + 0.01 * aux

        def per_example_loss(params, batch):
            logits, _ = forward(params, batch)
            return _masked_next_token_loss(logits, batch["tokens"], cfg)

        def init_cache(batch, max_len):
            return tfm.init_cache(cfg, batch, max_len)

        def prefill(params, batch, cache):
            logits, _, new_cache = tfm.forward(
                params, batch["tokens"], cfg, attn_mode=attn_mode,
                return_cache=True,
            )
            del logits
            lc = cache["k"].shape[2]
            pad = lc - new_cache["k"].shape[2]
            k = jnp.pad(new_cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(new_cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            return {"k": k.astype(cache["k"].dtype),
                    "v": v.astype(cache["v"].dtype),
                    "pos": new_cache["pos"]}

        def decode_step(params, cache, tokens):
            return tfm.decode_step(params, cache, tokens, cfg)

    elif fam == "ssm":
        def init(key):
            return ssm.init_ssm_model(key, cfg)

        def forward(params, batch):
            logits, aux, _ = ssm.ssm_forward(
                params, batch["tokens"], cfg, remat=remat
            )
            return logits, aux

        def loss(params, batch):
            logits, _ = forward(params, batch)
            return jnp.mean(_masked_next_token_loss(logits, batch["tokens"], cfg))

        def per_example_loss(params, batch):
            logits, _ = forward(params, batch)
            return _masked_next_token_loss(logits, batch["tokens"], cfg)

        def init_cache(batch, max_len):
            return ssm.ssm_init_cache(cfg, batch, max_len)

        def prefill(params, batch, cache):
            del cache  # SSM cache is constant-size; prefill rebuilds it
            return ssm.ssm_prefill(params, batch["tokens"], cfg)

        def decode_step(params, cache, tokens):
            return ssm.ssm_decode_step(params, cache, tokens, cfg)

    elif fam == "hybrid":
        def init(key):
            return hybrid.init_hybrid(key, cfg)

        def forward(params, batch):
            logits, aux, _ = hybrid.hybrid_forward(
                params, batch["tokens"], cfg, attn_mode=attn_mode, remat=remat
            )
            return logits, aux

        def loss(params, batch):
            logits, _ = forward(params, batch)
            return jnp.mean(_masked_next_token_loss(logits, batch["tokens"], cfg))

        def per_example_loss(params, batch):
            logits, _ = forward(params, batch)
            return _masked_next_token_loss(logits, batch["tokens"], cfg)

        def init_cache(batch, max_len):
            return hybrid.hybrid_init_cache(cfg, batch, max_len)

        def prefill(params, batch, cache):
            return hybrid.hybrid_prefill(
                params, batch["tokens"], cfg, cache, attn_mode=attn_mode
            )

        def decode_step(params, cache, tokens):
            return hybrid.hybrid_decode_step(params, cache, tokens, cfg)

    elif fam == "audio":
        def init(key):
            return encdec.init_encdec(key, cfg)

        def forward(params, batch):
            logits, aux, _ = encdec.encdec_forward(
                params, batch["tokens"], cfg, frames=batch["frames"],
                attn_mode=attn_mode, remat=remat,
            )
            return logits, aux

        def loss(params, batch):
            logits, _ = forward(params, batch)
            return jnp.mean(_masked_next_token_loss(logits, batch["tokens"], cfg))

        def per_example_loss(params, batch):
            logits, _ = forward(params, batch)
            return _masked_next_token_loss(logits, batch["tokens"], cfg)

        def init_cache(batch, max_len):
            return encdec.encdec_init_cache(cfg, batch, max_len)

        def prefill(params, batch, cache):
            return encdec.encdec_prefill_cross(
                params, batch["frames"], cfg, cache, attn_mode=attn_mode
            )

        def decode_step(params, cache, tokens):
            return encdec.encdec_decode_step(params, cache, tokens, cfg)

    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelBundle(
        cfg=cfg,
        init=init,
        loss=loss,
        per_example_loss=per_example_loss,
        forward=forward,
        init_cache=init_cache,
        prefill=prefill,
        decode_step=decode_step,
    )


def count_params(cfg: ArchConfig) -> int:
    """Analytic parameter count (no allocation) for roofline MODEL_FLOPS."""
    d, v = cfg.d_model, cfg.vocab_padded
    hd = cfg.head_dim
    total = v * d  # embed
    if not cfg.tie_embeddings:
        total += d * v  # head
    if cfg.family in ("dense", "moe", "vlm"):
        attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
        if cfg.n_experts > 0:
            n_mats = 3 if cfg.act == "silu" else 2
            ffn = d * cfg.n_experts + cfg.n_experts * n_mats * d * cfg.d_ff
        else:
            n_mats = 3 if cfg.act == "silu" else 2
            ffn = n_mats * d * cfg.d_ff
        total += cfg.n_layers * (attn + ffn)
    elif cfg.family == "ssm":
        total += cfg.n_layers * _mamba_layer_params(cfg)
    elif cfg.family == "hybrid":
        total += cfg.n_layers * _mamba_layer_params(cfg)
        attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
        n_mats = 3 if cfg.act == "silu" else 2
        total += attn + n_mats * d * cfg.d_ff  # one shared block
    elif cfg.family == "audio":
        d_enc = cfg.encoder_d_model or d
        attn_e = 4 * d_enc * cfg.n_heads * hd
        enc = cfg.encoder_layers * (attn_e + 2 * d_enc * cfg.d_ff)
        attn_d = 4 * d * cfg.n_heads * hd
        dec = cfg.n_layers * (2 * attn_d + 2 * d * cfg.d_ff)
        total += enc + dec
    return total


def _mamba_layer_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
    return d * d_in_proj + cfg.ssm_conv * conv_dim + cfg.d_inner * d


def active_params(cfg: ArchConfig) -> int:
    """Active (per-token) parameter count — MoE counts only top_k experts."""
    if cfg.n_experts == 0:
        return count_params(cfg)
    total = count_params(cfg)
    n_mats = 3 if cfg.act == "silu" else 2
    expert_p = n_mats * cfg.d_model * cfg.d_ff
    total -= cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert_p
    return total
