"""Mamba2 (SSD — state-space duality) blocks and the pure-SSM model.

Implements the chunked SSD algorithm of arXiv:2405.21060: the sequence is
split into chunks of Q tokens; within a chunk the recurrence is evaluated in
its "attention-like" quadratic dual form (MXU-friendly matmuls), and chunk
states are carried by a lax.scan — O(L·Q) work, O(L/Q) sequential depth.
Decode keeps a constant-size (H, P, N) state per layer: the long_500k shape
is naturally sub-quadratic here.

Layer layout follows the Mamba2 reference: in_proj -> (z, x, B, C, dt);
short causal depthwise conv over (x, B, C); SSD; gated RMSNorm; out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import (
    apply_norm,
    cast_params_for_compute,
    dense_init,
    embed_init,
    rmsnorm_init,
    stack_init,
    unroll_arg,
)

NEG_INF = -1e30


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] for i>=j,
    -inf above the diagonal."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, d, NEG_INF)


def ssd_chunked(
    x: jnp.ndarray,   # (B, L, H, P) inputs (pre-multiplied by nothing)
    dt: jnp.ndarray,  # (B, L, H) positive step sizes
    A: jnp.ndarray,   # (H,) negative decay rates
    Bm: jnp.ndarray,  # (B, L, G, N)
    Cm: jnp.ndarray,  # (B, L, G, N)
    chunk: int,
    initial_state: jnp.ndarray | None = None,  # (B, H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    c = l // chunk

    f32 = jnp.float32
    xc = x.reshape(b, c, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, c, chunk, h).astype(f32)
    Bc = Bm.reshape(b, c, chunk, g, n).astype(f32)
    Cc = Cm.reshape(b, c, chunk, g, n).astype(f32)

    dA = dtc * A.astype(f32)  # (b, c, q, h)
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # --- intra-chunk (diagonal blocks), dual quadratic form ---
    Lmat = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))  # (b, c, h, q, q)
    # scores over state dim, broadcasting groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b, c, q, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)  # (b,c,h,q,k)
    xdt = xc * dtc[..., None]  # (b, c, q, h, p)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores * Lmat, xdt)

    # --- chunk states ---
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)  # (b, c, q, h)
    states = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", Bh, decay_states * dtc, xc
    )  # (b, c, h, p, n)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b, c, h)
    s0 = (
        jnp.zeros((b, h, p, n), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def scan_body(s, inp):
        st, dec = inp  # st (b,h,p,n), dec (b,h)
        s_new = s * dec[:, :, None, None] + st
        return s_new, s  # emit the state *entering* this chunk

    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    final_state, prev_states = jax.lax.scan(scan_body, s0, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b, c, h, p, n)

    # --- off-diagonal contribution from carried states ---
    state_decay_in = jnp.exp(cum)  # (b, c, q, h)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, state_decay_in
    )
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x: jnp.ndarray,      # (B, H, P)
    dt: jnp.ndarray,     # (B, H)
    A: jnp.ndarray,      # (H,)
    Bm: jnp.ndarray,     # (B, G, N)
    Cm: jnp.ndarray,     # (B, G, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence. Returns (y (B, H, P), new_state)."""
    f32 = jnp.float32
    h = x.shape[1]
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(f32)  # (B, H, N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(f32)
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))  # (B, H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(f32), x.astype(f32), Bh)
    new_state = state.astype(f32) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state.astype(state.dtype)


# --------------------------------------------------------------------------
# Mamba2 layer
# --------------------------------------------------------------------------


def _conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba_layer(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    h = cfg.ssm_heads
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + h
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1]
    dt0 = jnp.exp(
        jax.random.uniform(ks[3], (h,)) * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3)
    )
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "ln": rmsnorm_init(cfg.d_model, dtype),
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, _conv_dim(cfg))) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((_conv_dim(cfg),), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (h,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "gate_ln": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": dense_init(ks[4], cfg.d_inner, cfg.d_model, dtype),
    }


def _causal_depthwise_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """u: (B, L, C); w: (K, C) — causal depthwise conv via shifted adds
    (K is tiny: 4)."""
    k = w.shape[0]
    out = jnp.zeros_like(u)
    for i in range(k):
        shift = k - 1 - i
        shifted = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu(out + b)


def _split_in_proj(zxbcdt, cfg: ArchConfig):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + _conv_dim(cfg)]
    dt = zxbcdt[..., di + _conv_dim(cfg) :]
    return z, xbc, dt


def apply_mamba_layer(p, hidden, *, cfg: ArchConfig, return_state: bool = False):
    """Full-sequence Mamba2 block with residual. hidden: (B, L, D).

    ``return_state=True`` additionally returns the decode cache entry for
    this layer: the final SSD state and the last (K-1) pre-conv tokens —
    used by the prefill path."""
    b, l, _ = hidden.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    x_in = apply_norm("rmsnorm", p["ln"], hidden)
    zxbcdt = x_in @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_in_proj(zxbcdt, cfg)
    xbc = _causal_depthwise_conv(xbc_raw, p["conv_w"], p["conv_b"])
    x = xbc[..., :di].reshape(b, l, h, cfg.ssm_headdim)
    Bm = xbc[..., di : di + g * n].reshape(b, l, g, n)
    Cm = xbc[..., di + g * n :].reshape(b, l, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(x, dt, A, Bm, Cm, chunk=min(cfg.ssm_chunk, l))
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, l, di)
    y = apply_norm("rmsnorm", p["gate_ln"], y * jax.nn.silu(z))
    out = hidden + y @ p["out_proj"]
    if return_state:
        k = p["conv_w"].shape[0]
        state = {
            "ssm": final_state,
            "conv": xbc_raw[:, l - (k - 1):, :],
        }
        return out, state
    return out


def init_mamba_cache(cfg: ArchConfig, n_layers: int, batch: int, dtype=None):
    dtype = dtype or jnp.float32
    return {
        "ssm": jnp.zeros(
            (n_layers, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), dtype
        ),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, _conv_dim(cfg)),
                          cfg.compute_dtype_jnp()),
    }


def decode_mamba_layer(p, hidden, layer_cache, *, cfg: ArchConfig):
    """Single-token Mamba2 step. hidden (B, 1, D)."""
    b = hidden.shape[0]
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    x_in = apply_norm("rmsnorm", p["ln"], hidden)
    zxbcdt = (x_in @ p["in_proj"])[:, 0]  # (B, d_in_proj)
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)
    # conv over (cached window ++ current)
    win = jnp.concatenate([layer_cache["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    conv = jax.nn.silu(
        jnp.sum(win * p["conv_w"][None], axis=1) + p["conv_b"]
    )
    new_conv = win[:, 1:]
    x = conv[..., :di].reshape(b, h, cfg.ssm_headdim)
    Bm = conv[..., di : di + g * n].reshape(b, g, n)
    Cm = conv[..., di + g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_decode_step(layer_cache["ssm"], x, dt, A, Bm, Cm)
    y = y + x * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, di)
    y = apply_norm("rmsnorm", p["gate_ln"], y * jax.nn.silu(z[:, None, :]))
    return hidden + y @ p["out_proj"], {"ssm": new_state, "conv": new_conv}


# --------------------------------------------------------------------------
# Pure-SSM model (mamba2-370m)
# --------------------------------------------------------------------------


def init_ssm_model(key, cfg: ArchConfig):
    dtype = cfg.param_dtype_jnp()
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": embed_init(k1, cfg.vocab_padded, cfg.d_model, dtype),
        "layers": stack_init(lambda k: init_mamba_layer(k, cfg), k2, cfg.n_layers),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "head": dense_init(k3, cfg.d_model, cfg.vocab_padded, dtype),
    }


def ssm_forward(params, tokens, cfg: ArchConfig, *, remat: bool = False):
    compute = cfg.compute_dtype_jnp()
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)

    def body(h, layer_p):
        fn = apply_mamba_layer
        if remat:
            fn = jax.checkpoint(lambda p_, h_: apply_mamba_layer(p_, h_, cfg=cfg))
            return fn(layer_p, h), None
        return fn(layer_p, h, cfg=cfg), None

    h, _ = jax.lax.scan(body, h, params["layers"],
                        unroll=unroll_arg(cfg.scan_unroll))
    h = apply_norm("rmsnorm", params["ln_f"], h)
    logits = h @ params["head"]
    return logits, jnp.zeros((), jnp.float32), None


def ssm_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    del max_len  # constant-size state: the whole point
    cache = init_mamba_cache(cfg, cfg.n_layers, batch, dtype)
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


def ssm_prefill(params, tokens, cfg: ArchConfig):
    """Run the chunked scan over the prompt, capturing per-layer decode
    state (SSD state + conv tail). Returns a filled cache."""
    compute = cfg.compute_dtype_jnp()
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)

    def body(h, layer_p):
        h, st = apply_mamba_layer(layer_p, h, cfg=cfg, return_state=True)
        return h, st

    _, states = jax.lax.scan(body, h, params["layers"],
                             unroll=unroll_arg(cfg.scan_unroll))
    return {
        "ssm": states["ssm"].astype(jnp.float32),
        "conv": states["conv"].astype(compute),
        "pos": jnp.asarray(tokens.shape[1], jnp.int32),
    }


def ssm_decode_step(params, cache, tokens, cfg: ArchConfig):
    compute = cfg.compute_dtype_jnp()
    h = params["embed"][tokens].astype(compute)
    params = cast_params_for_compute(params, compute)

    def body(h, xs):
        layer_p, layer_cache = xs
        h, new_c = decode_mamba_layer(layer_p, h, layer_cache, cfg=cfg)
        return h, new_c

    h, new_caches = jax.lax.scan(
        body, h, (params["layers"], {"ssm": cache["ssm"], "conv": cache["conv"]}),
        unroll=unroll_arg(cfg.scan_unroll),
    )
    h = apply_norm("rmsnorm", params["ln_f"], h)
    logits = h @ params["head"]
    return logits, {**new_caches, "pos": cache["pos"] + 1}
