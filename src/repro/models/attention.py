"""Attention: reference, blocked (flash-style, pure JAX), SWA, decode.

Three execution tiers:

- ``ref_attention``   — O(L²) materialized scores. Test oracle; small shapes.
- ``blocked_attention`` — the flash algorithm (online softmax over KV blocks)
  written with a lax.scan over the *static* list of (q-block, kv-block)
  pairs. Causality and sliding windows prune the pair list at trace time, so
  compiled FLOPs match the true masked cost (≈½ of naive for causal, ∝W for
  windowed) and peak memory is O(block²) — this is what the 32k-prefill
  dry-runs lower. It is also structurally identical to the Pallas
  ``flash_attention`` kernel (kernels/flash_attention.py), which replaces it
  on real TPU hardware.
- ``decode_attention`` — one query token vs a (possibly sequence-sharded)
  KV cache; exposes (m, l, o) partials so the launch layer can combine
  shards with a stable-softmax psum (flash-decoding on ICI).

All functions take GQA-layout tensors:
  q: (B, Lq, Hq, hd)    k, v: (B, Lkv, Hkv, hd)   with Hq = G * Hkv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _split_gqa(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, L, Hq, hd) -> (B, L, Hkv, G, hd)."""
    b, l, hq, hd = q.shape
    return q.reshape(b, l, n_kv, hq // n_kv, hd)


def ref_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Materialized attention. Oracle for blocked/Pallas paths."""
    b, lq, hq, hd = q.shape
    n_kv = k.shape[2]
    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
    scores = jnp.einsum("blkgd,bmkd->bkglm", qg, k32) / np.sqrt(hd)
    pos_q = jnp.arange(lq) + q_offset
    pos_k = jnp.arange(k.shape[1])
    mask = jnp.ones((lq, k.shape[1]), dtype=bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        mask &= pos_q[:, None] - pos_k[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkglm,bmkd->blkgd", probs, v32)
    return out.reshape(b, lq, hq, hd).astype(q.dtype)


def _block_pairs(n_q: int, n_kv: int, causal: bool, window: int | None,
                 q_block: int, kv_block: int):
    """Static (qi, ki) pair list. Causality/window prune at trace time.
    Bounds are computed in *positions* so unequal q/kv block sizes are
    handled exactly."""
    pairs = []
    for qi in range(n_q):
        q_lo = qi * q_block
        q_hi = q_lo + q_block - 1
        lo, hi = 0, n_kv - 1
        if causal:
            hi = min(hi, q_hi // kv_block)
        if window is not None:
            lo = max(lo, (q_lo - window + 1) // kv_block)
        for ki in range(lo, hi + 1):
            pairs.append((qi, ki))
    return np.array(pairs, dtype=np.int32)


def _fit_block(length: int, block: int) -> int:
    """Largest divisor of ``length`` that is <= ``block`` (lengths like
    whisper's 1500 encoder frames are not powers of two)."""
    block = min(block, length)
    while length % block:
        block -= 1
    return block


def blocked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    dyn_window: jnp.ndarray | None = None,
    q_block: int = 512,
    kv_block: int = 512,
    unroll: int | bool = 1,
) -> jnp.ndarray:
    """Flash-style attention via scan over static block pairs.

    ``window`` is a static sliding-window bound used to prune block pairs;
    ``dyn_window`` is an optional *traced* per-call window (used by
    local:global stacks where the window varies per layer inside a scan) —
    it can only tighten the mask, never widen past ``window``.
    """
    b, lq, hq, hd = q.shape
    lkv = k.shape[1]
    n_kvh = k.shape[2]
    g = hq // n_kvh
    scale = 1.0 / np.sqrt(hd)

    q_block = _fit_block(lq, q_block)
    kv_block = _fit_block(lkv, kv_block)
    n_q, n_k = lq // q_block, lkv // kv_block

    pairs = _block_pairs(n_q, n_k, causal, window, q_block, kv_block)

    qg = _split_gqa(q, n_kvh)  # (B, L, Hkv, G, hd)
    # accumulators in fp32
    acc = jnp.zeros((n_q, b, n_kvh, g, q_block, hd), jnp.float32)
    m = jnp.full((n_q, b, n_kvh, g, q_block), NEG_INF, jnp.float32)
    l = jnp.zeros((n_q, b, n_kvh, g, q_block), jnp.float32)

    pos_in_q = jnp.arange(q_block)
    pos_in_k = jnp.arange(kv_block)

    def body(carry, pair):
        acc, m, l = carry
        qi, ki = pair[0], pair[1]
        qb = jax.lax.dynamic_slice_in_dim(qg, qi * q_block, q_block, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, axis=1)
        s = (
            jnp.einsum(
                "blkgd,bmkd->bkglm",
                qb.astype(jnp.float32),
                kb.astype(jnp.float32),
            )
            * scale
        )  # (B, Hkv, G, q_block, kv_block)
        pq = qi * q_block + pos_in_q
        pk = ki * kv_block + pos_in_k
        mask = jnp.ones((q_block, kv_block), bool)
        if causal:
            mask &= pq[:, None] >= pk[None, :]
        if window is not None:
            mask &= pq[:, None] - pk[None, :] < window
        if dyn_window is not None:
            mask &= pq[:, None] - pk[None, :] < dyn_window
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        m_prev = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)

        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        a_new = a_prev * alpha[..., None] + jnp.einsum(
            "bkglm,bmkd->bkgld", p, vb.astype(jnp.float32)
        )
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), pairs, unroll=unroll)
    # (n_q, B, Hkv, G, q_block, hd) -> (B, L, Hq, hd)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 0, 3)  # (B, Hkv, G, n_q, q_block, hd)
    out = out.reshape(b, n_kvh, g, lq, hd)
    out = jnp.moveaxis(out, 3, 1).reshape(b, lq, hq, hd)
    return out.astype(q.dtype)


def attention(
    q, k, v, *, mode: str = "blocked", causal: bool = True,
    window: int | None = None, dyn_window=None,
    q_block: int = 512, kv_block: int = 512, unroll: int | bool = 1,
):
    if mode == "ref":
        out = ref_attention(q, k, v, causal=causal, window=window)
        if dyn_window is not None:
            # ref path with traced window: recompute mask via blocked path
            out = blocked_attention(
                q, k, v, causal=causal, window=window, dyn_window=dyn_window,
                q_block=q_block, kv_block=kv_block,
            )
        return out
    if mode == "blocked":
        return blocked_attention(
            q, k, v, causal=causal, window=window, dyn_window=dyn_window,
            q_block=q_block, kv_block=kv_block, unroll=unroll,
        )
    if mode == "pallas":
        from repro.kernels import ops as kops

        assert dyn_window is None, "pallas path requires static windows"
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention mode {mode!r}")


# --------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# --------------------------------------------------------------------------


def decode_attention_parts(
    q: jnp.ndarray,  # (B, 1, Hq, hd)
    k_cache: jnp.ndarray,  # (B, Lc, Hkv, hd) — possibly a shard
    v_cache: jnp.ndarray,
    positions: jnp.ndarray,  # (Lc,) global positions of the cache shard
    cur_pos: jnp.ndarray,  # () global position of the new token
    window: int | None = None,
    dyn_window: jnp.ndarray | None = None,
):
    """Stable-softmax partials (m, l, o) over this cache shard.

    Combine across shards with: M=max m; l'=Σ l·e^{m-M}; o'=Σ o·e^{m-M}.
    """
    b, _, hq, hd = q.shape
    n_kv = k_cache.shape[2]
    # _split_gqa gives (B, 1, Hkv, G, hd); drop the length-1 query axis
    qg = _split_gqa(q, n_kv)[:, 0].astype(jnp.float32)  # (B, Hkv, G, hd)
    s = jnp.einsum(
        "bkgd,bmkd->bkgm", qg, k_cache.astype(jnp.float32)
    ) / np.sqrt(hd)  # (B, Hkv, G, Lc)
    valid = positions[None, None, None, :] <= cur_pos
    if window is not None:
        valid &= cur_pos - positions[None, None, None, :] < window
    if dyn_window is not None:
        valid &= cur_pos - positions[None, None, None, :] < dyn_window
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B, Hkv, G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgm,bmkd->bkgd", p, v_cache.astype(jnp.float32))
    return m, l, o


def combine_decode_parts(m, l, o, axis_name=None):
    """Finish decode attention from (m, l, o); psum across ``axis_name``
    shards if given (flash-decoding combine)."""
    if axis_name is not None:
        M = jax.lax.pmax(m, axis_name)
        alpha = jnp.exp(m - M)
        l = jax.lax.psum(l * alpha, axis_name)
        o = jax.lax.psum(o * alpha[..., None], axis_name)
    out = o / jnp.maximum(l[..., None], 1e-30)
    b, n_kv, g, hd = out.shape
    return out.reshape(b, 1, n_kv * g, hd)


def decode_attention(
    q, k_cache, v_cache, cur_pos, *, window=None, dyn_window=None, axis_name=None
):
    lc = k_cache.shape[1]
    if axis_name is None:
        positions = jnp.arange(lc)
    else:
        idx = jax.lax.axis_index(axis_name)
        positions = idx * lc + jnp.arange(lc)
    m, l, o = decode_attention_parts(
        q, k_cache, v_cache, positions, cur_pos, window=window, dyn_window=dyn_window
    )
    return combine_decode_parts(m, l, o, axis_name=axis_name).astype(q.dtype)
