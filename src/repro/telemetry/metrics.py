"""Traced round-metric primitives for the telemetry plane.

Every function here is pure jnp on its inputs and batch-polymorphic over
leading axes (the multi-seed driver vmaps states but computes metrics
OUTSIDE the vmap, so a batched run's ``u`` arrives as (k, N, S), its
plane as (k, S, N, X), a per-seed adjacency as (k, N, N)).  Reductions
therefore run over trailing axes only.

``make_collector`` builds the per-round collection closure the experiment
driver (experiments/runner.py) splices into the round program: it runs
inside the SAME jitted dispatch as the training step (the lax.scan body
under ``scan_rounds=True``), which is what makes every stream bit-identical
between the loop and scan engines and keeps collection at zero extra
dispatches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.telemetry.config import TelemetryConfig

# the stream names in export order (the JSONL schema table in README)
STREAMS = ("logical_bytes", "wire_bytes", "u_entropy", "u_drift",
           "consensus", "degree", "spectral_gap", "stale_hist",
           "n_inactive", "density", "mask_churn")


def mixture_entropy(u: jnp.ndarray) -> jnp.ndarray:
    """Mean per-client entropy of the (..., N, S) soft cluster weights —
    0 for hard assignments, log(S) at the uniform mixture."""
    p = u.astype(jnp.float32)
    h = -jnp.sum(jnp.where(p > 0.0, p * jnp.log(p), 0.0), axis=-1)
    return jnp.mean(h, axis=-1)


def mixture_drift(u_old: jnp.ndarray, u_new: jnp.ndarray) -> jnp.ndarray:
    """Frobenius norm of the soft-assignment update ‖u_t − u_{t−1}‖."""
    d = (u_new.astype(jnp.float32) - u_old.astype(jnp.float32))
    return jnp.sqrt(jnp.sum(d * d, axis=(-2, -1)))


def consensus_residual(plane: jnp.ndarray) -> jnp.ndarray:
    """Per-cluster consensus residual on a (..., S, N, X) plane:
    ‖C_i − mean_i(C)‖² summed over clients and params, / N — the same
    normalization as core/fedspd's per-cluster consensus metric."""
    p32 = plane.astype(jnp.float32)
    mean = jnp.mean(p32, axis=-2, keepdims=True)
    return jnp.sum(jnp.square(p32 - mean), axis=(-2, -1)) / plane.shape[-2]


def effective_degree(adj: jnp.ndarray) -> jnp.ndarray:
    """Mean degree of the binarized effective (..., N, N) adjacency —
    after dropout masks and heterogeneity weights zeroed their links."""
    n = adj.shape[-1]
    a = (adj > 0.0).astype(jnp.float32)
    a = a * (1.0 - jnp.eye(n, dtype=jnp.float32))
    return jnp.sum(a, axis=(-2, -1)) / n


def spectral_gap_proxy(adj: jnp.ndarray, iters: int = 8) -> jnp.ndarray:
    """1 − ρ proxy for the Metropolis mixing matrix of the effective
    adjacency, where ρ = max |eigenvalue ≠ 1| governs gossip convergence.

    Builds the symmetric doubly-stochastic Metropolis W
    (w_ij = a_ij / (1 + max(d_i, d_j)), diagonal absorbs the deficit),
    deflates the all-ones eigenvector, and runs ``iters`` fixed power
    iterations from a deterministic start vector — traced, cheap
    (``iters`` N×N matvecs), and identical under both round engines.
    An empty effective graph (everyone isolated) reports gap 0."""
    n = adj.shape[-1]
    eye = jnp.eye(n, dtype=jnp.float32)
    a = (adj > 0.0).astype(jnp.float32) * (1.0 - eye)
    deg = jnp.sum(a, axis=-1)
    mx = jnp.maximum(deg[..., :, None], deg[..., None, :])
    w = a / (1.0 + mx)
    w = w + eye * (1.0 - jnp.sum(w, axis=-1, keepdims=True))
    v = jnp.broadcast_to(jnp.linspace(-1.0, 1.0, n, dtype=jnp.float32),
                         adj.shape[:-1])
    rho = jnp.zeros(adj.shape[:-2], jnp.float32)
    for _ in range(int(iters)):
        v = v - jnp.mean(v, axis=-1, keepdims=True)      # deflate 1-vec
        norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
        v = v / jnp.maximum(norm, 1e-12)
        v = jnp.einsum("...ij,...j->...i", w, v)
        rho = jnp.sqrt(jnp.sum(v * v, axis=-1))
    return jnp.maximum(0.0, 1.0 - rho)


def staleness_histogram(stale: jnp.ndarray, bins: int) -> jnp.ndarray:
    """(..., N) integer staleness counters -> (..., bins) counts: exact
    bins for staleness 0..bins-2 plus an overflow bin for >= bins-1."""
    clipped = jnp.clip(stale, 0, bins - 1)
    onehot = jax.nn.one_hot(clipped, bins, dtype=jnp.float32)
    return jnp.sum(onehot, axis=-2)


def inactive_count(weights: jnp.ndarray) -> jnp.ndarray:
    """Clients contributing nothing this round (stragglers + offline):
    zero entries of the (..., N) activity-weight vector."""
    return jnp.sum((weights <= 0.0).astype(jnp.float32), axis=-1)


def mask_density(mask: jnp.ndarray) -> jnp.ndarray:
    """Mean active fraction of the (..., N, X) sparse masks — constant by
    construction under the exact-count RigL update (core/sparse), so a
    drifting stream IS the regression signal."""
    return jnp.mean(mask.astype(jnp.float32), axis=(-2, -1))


def mask_churn(mask_old: jnp.ndarray, mask_new: jnp.ndarray) -> jnp.ndarray:
    """Fraction of coordinates whose mask bit flipped this round — 0 on
    frozen rounds, 2·prune_rate·density at a full RigL update."""
    d = jnp.abs(mask_new.astype(jnp.float32) - mask_old.astype(jnp.float32))
    return jnp.mean(d, axis=(-2, -1))


def flatten_centers(centers, batch_ndim: int = 0):
    """Ravel a pytree of (S, N, ...) center leaves (with ``batch_ndim``
    leading seed axes) into one (..., S, N, X) plane — already-packed
    plane states pass through.  Raises on leaves that do not carry the
    (S, N) leading structure; callers probe once host-side."""
    leaves = jax.tree.leaves(centers)
    if len(leaves) == 1 and leaves[0].ndim == batch_ndim + 3:
        return leaves[0]
    lead = leaves[0].shape[:batch_ndim + 2]
    flat = []
    for leaf in leaves:
        if leaf.shape[:batch_ndim + 2] != lead:
            raise ValueError("centers leaves disagree on (S, N) structure")
        flat.append(jnp.reshape(leaf, lead + (-1,)))
    return jnp.concatenate(flat, axis=-1)


def make_collector(cfg: TelemetryConfig, *, batch_shape: tuple = (),
                   n_clusters: int, n_clients: int, wire_ratio: float = 1.0,
                   per_round_bytes: float | None = None,
                   has_u: bool = True, has_plane: bool = True,
                   has_mask: bool = False):
    """Build the per-round collection closure the driver jits into the
    round program.

    ``collect(old_state, new_state, adj, weights, stale)`` returns the
    {stream: array} pytree for ONE round.  ``adj`` is the round's
    effective traced adjacency (post dropout and heterogeneity weights);
    ``weights``/``stale`` are the heterogeneity activity vector and
    updated staleness counters (None without a system model — the
    streams degrade to all-active constants).  ``per_round_bytes`` is the
    static round cost for methods without tracked comm accounting (then
    the state's ``comm_bytes`` delta is not read).

    Every output is broadcast to its full per-seed shape (scalars to
    ``batch_shape``), so the host-side slicing per seed is uniform.
    """
    bshape = tuple(batch_shape)
    s, n = int(n_clusters), int(n_clients)
    bins = int(cfg.staleness_bins)
    nan = jnp.float32(jnp.nan)

    def full(v, tail=()):
        return jnp.broadcast_to(jnp.asarray(v, jnp.float32), bshape + tail)

    def collect(old, new, adj, weights=None, stale=None) -> dict:
        if per_round_bytes is not None:
            logical = full(per_round_bytes)
        else:
            logical = full(new.comm_bytes - old.comm_bytes)
        out = {
            "logical_bytes": logical,
            "wire_bytes": logical * jnp.float32(wire_ratio),
            "u_entropy": full(mixture_entropy(new.u) if has_u else nan),
            "u_drift": full(mixture_drift(old.u, new.u) if has_u else nan),
        }
        if has_plane:
            plane = flatten_centers(new.centers, batch_ndim=len(bshape))
            out["consensus"] = full(consensus_residual(plane), (s,))
        else:
            out["consensus"] = full(nan, (s,))
        out["degree"] = full(effective_degree(adj))
        out["spectral_gap"] = (
            full(spectral_gap_proxy(adj, cfg.power_iters))
            if cfg.spectral_gap else full(nan)
        )
        if stale is None:
            stale_v = jnp.zeros((n,), jnp.int32)
        else:
            stale_v = stale
        out["stale_hist"] = full(staleness_histogram(stale_v, bins), (bins,))
        out["n_inactive"] = full(
            inactive_count(weights) if weights is not None else 0.0
        )
        if has_mask:
            out["density"] = full(mask_density(new.mask))
            out["mask_churn"] = full(mask_churn(old.mask, new.mask))
        else:
            out["density"] = full(nan)
            out["mask_churn"] = full(nan)
        return out

    return collect
