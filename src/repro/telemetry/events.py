"""Structured JSONL event log: the host-side export of a run's telemetry.

One JSON object per line.  Schema (README "Observability" has the full
table):

  {"event": "run_meta",  "method", "rounds", "n_clients", "n_clusters",
                         "seed", "streams": [...]}
  {"event": "round",     "round": r, <one key per stream — scalars as
                         floats, per-cluster / histogram streams as
                         lists>}
  {"event": "summary",   "mean_acc", "std_acc", "comm_bytes",
                         "wire_bytes", "wall_s", "n_compiles",
                         "n_dispatches", ["staleness"]}

Serve-side events (launch/serve --telemetry-out):

  {"event": "serve_meta",    "codec", "n_clusters", "plane_bytes"}
  {"event": "serve_batch",   "entry", "batch", "latency_ms"}
  {"event": "serve_summary", "requests", "qps", "p50_ms", "p95_ms",
                             "p99_ms", "n_compiles", "n_dispatches",
                             "dequant_calls"}

Floats are written as Python floats (repr-exact JSON), so write → parse
round-trips every value bit-exactly at float64 — float32 stream values
widen exactly on the way in (asserted in tests/test_telemetry.py).
"""
from __future__ import annotations

import json

import numpy as np


def jsonable(v):
    """np scalars/arrays -> exact-round-trip JSON values."""
    if isinstance(v, np.ndarray):
        return [jsonable(x) for x in v.tolist()] \
            if v.ndim > 0 else jsonable(v.item())
    if isinstance(v, (np.floating, np.integer, np.bool_)):
        return v.item()
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: jsonable(x) for k, x in v.items()}
    return v


def write_events(path: str, events: list[dict]) -> None:
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(jsonable(e)) + "\n")


def read_events(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def run_events(result, meta: dict | None = None) -> list[dict]:
    """RunResult -> the event list.  ``result.telemetry`` (the traced
    round streams) expands into one ``round`` event per round; a run
    without the traced plane still gets run_meta + summary (plus its
    eval curve as sparse ``round`` events)."""
    tel = getattr(result, "telemetry", None) or {}
    streams = dict(tel.get("streams", {}))
    head = {
        "event": "run_meta",
        "method": result.method,
        "rounds": tel.get("rounds", len(result.curve)),
        "streams": sorted(streams),
    }
    head.update(meta or {})
    events = [head]
    curve = dict(result.curve)
    rounds = int(tel.get("rounds", 0))
    if streams:
        for r in range(rounds):
            row = {"event": "round", "round": r}
            for name in sorted(streams):
                row[name] = streams[name][r]
            if r in curve:
                row["train_acc"] = curve[r]
            events.append(row)
    else:
        for r, acc in result.curve:
            events.append({"event": "round", "round": r, "train_acc": acc})
    summary = {
        "event": "summary",
        "mean_acc": result.mean_acc,
        "std_acc": result.std_acc,
        "comm_bytes": result.comm_bytes,
        "wire_bytes": result.wire_bytes,
        "wall_s": result.wall_s,
    }
    for k in ("n_compiles", "n_dispatches", "staleness"):
        if k in result.extras:
            summary[k] = result.extras[k]
    events.append(summary)
    return events


def write_run_jsonl(path: str, result, meta: dict | None = None) -> None:
    """The ``--telemetry-out`` exporter: RunResult -> JSONL file."""
    write_events(path, run_events(result, meta))


def streams_from_events(events: list[dict]) -> dict:
    """Parse ``round`` events back into {stream: (rounds, ...) float64
    array} — the inverse of ``run_events`` for the round-trip tests and
    the dashboard."""
    rows = [e for e in events if e.get("event") == "round"]
    rows.sort(key=lambda e: e["round"])
    out = {}
    if not rows:
        return out
    for name in rows[0]:
        if name in ("event", "round"):
            continue
        if all(name in e for e in rows):
            out[name] = np.asarray([e[name] for e in rows])
    return out
