"""Host-side counters: jit-cache compile counts and serve-path latency.

``compile_count`` is THE one compile-count accounting used across the
repo — the experiment driver (``extras["n_compiles"]``), the serve path
(``ClusterPlaneServer.n_compiles``), and the benches all report through
it, so "one compile" means the same thing everywhere.
"""
from __future__ import annotations

import time


def compile_count(fn) -> int:
    """Number of programs a ``jax.jit``-wrapped callable has compiled.

    Reads the jit cache size — ``_cache_size`` is a private jax API, so
    its absence on other jax versions returns -1 (diagnostic unknown)
    instead of failing a finished run.
    """
    try:
        return int(getattr(fn, "_cache_size", lambda: -1)())
    except Exception:
        return -1


class LatencyStats:
    """Per-batch serve latency accumulator (host wall clock).

    ``record`` takes one blocking-measured batch; ``snapshot`` reports
    the latency percentiles and sustained QPS (requests served over the
    recording wall-span).  Percentiles use the nearest-rank method on the
    sorted sample — exact, deterministic, no interpolation surprises in
    the round-trip tests.
    """

    def __init__(self):
        self.latencies_s: list[float] = []
        self.requests = 0
        self._t_first = None
        self._t_last = None

    def record(self, seconds: float, batch: int = 1) -> None:
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now - seconds
        self._t_last = now
        self.latencies_s.append(float(seconds))
        self.requests += int(batch)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile of the recorded batch latencies (s)."""
        if not self.latencies_s:
            return float("nan")
        xs = sorted(self.latencies_s)
        rank = max(1, -(-int(p) * len(xs) // 100))   # ceil(p/100 * n)
        return xs[min(rank, len(xs)) - 1]

    @property
    def qps(self) -> float:
        if not self.latencies_s:
            return 0.0
        span = (self._t_last or 0.0) - (self._t_first or 0.0)
        busy = sum(self.latencies_s)
        denom = span if span > 0 else busy
        return self.requests / denom if denom > 0 else 0.0

    def snapshot(self) -> dict:
        return {
            "batches": len(self.latencies_s),
            "requests": self.requests,
            "p50_ms": self.percentile(50) * 1e3,
            "p95_ms": self.percentile(95) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
            "qps": self.qps,
        }
