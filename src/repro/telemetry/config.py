"""TelemetryConfig: the frozen knob block for the traced round-metrics
plane.

Attached to ``RunConfig(telemetry=...)`` (experiments/config.py).  When
set, the experiment driver computes a per-round metrics pytree INSIDE the
round program — the streams ride the existing ``lax.scan`` ys under
``scan_rounds=True`` and the existing per-round jitted dispatch under the
loop engine, so collection costs zero extra dispatches and leaves the
compile count untouched (asserted in tests/test_telemetry.py).  Both
engines evaluate the identical traced expressions, so every stream is
bit-identical between them.

Streams (all per round; shapes per seed):

  logical_bytes   ()   logical comm this round (uncompressed dtypes)
  wire_bytes      ()   physical bytes under the run's codec (static ratio)
  u_entropy       ()   mean per-client entropy of the soft cluster weights
  u_drift         ()   ‖u_t − u_{t−1}‖_F — soft-assignment drift
  consensus       (S,) per-cluster consensus residual ‖C_i − mean(C)‖²/N
  degree          ()   mean effective-adjacency degree (post dropout/het)
  spectral_gap    ()   1 − ρ(W) proxy of the Metropolis mixing matrix
  stale_hist      (B,) staleness histogram (B = ``staleness_bins``)
  n_inactive      ()   stragglers + offline clients this round
  density         ()   mean active fraction of the sparse masks (DisPFL)
  mask_churn      ()   fraction of mask bits flipped this round

Streams whose inputs a run lacks (no ``u`` on the state, no plane-shaped
centers, no sparse masks) are emitted as NaN constants of the right
static shape, so the payload structure is a function of the config alone.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """How much the traced round-metrics plane collects.

    round_metrics   master switch for the per-round traced streams
    spectral_gap    include the mixing-matrix spectral-gap proxy (a few
                    extra N×N matmuls per round; disable at very large N)
    power_iters     deflated power-iteration steps for the gap proxy
    staleness_bins  histogram bins: counts of staleness == 0..B-2 plus an
                    overflow bin for >= B-1
    """

    round_metrics: bool = True
    spectral_gap: bool = True
    power_iters: int = 8
    staleness_bins: int = 5

    def __post_init__(self):
        if self.power_iters < 1:
            raise ValueError(
                f"TelemetryConfig.power_iters={self.power_iters!r} must "
                "be >= 1"
            )
        if self.staleness_bins < 2:
            raise ValueError(
                f"TelemetryConfig.staleness_bins={self.staleness_bins!r} "
                "must be >= 2 (one exact bin + overflow)"
            )

    @property
    def enabled(self) -> bool:
        return self.round_metrics
