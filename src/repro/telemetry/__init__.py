# Telemetry layer: the traced round-metrics plane (TelemetryConfig on
# RunConfig — per-round streams collected INSIDE the round program, zero
# extra dispatches, bit-identical between the loop and lax.scan engines),
# host-side exporters (structured JSONL event log + summary tables), the
# one compile-count accounting (counters.compile_count), serve-path
# latency stats, and jax.profiler trace hooks (Perfetto).
from repro.telemetry.config import TelemetryConfig  # noqa: F401
from repro.telemetry.counters import (  # noqa: F401
    LatencyStats,
    compile_count,
)
from repro.telemetry.events import (  # noqa: F401
    read_events,
    run_events,
    streams_from_events,
    write_events,
    write_run_jsonl,
)
from repro.telemetry.metrics import (  # noqa: F401
    STREAMS,
    consensus_residual,
    effective_degree,
    inactive_count,
    make_collector,
    mixture_drift,
    mixture_entropy,
    spectral_gap_proxy,
    staleness_histogram,
)
from repro.telemetry.profile import (  # noqa: F401
    annotate,
    step_annotation,
    trace_session,
)
from repro.telemetry.summary import summary_table  # noqa: F401
