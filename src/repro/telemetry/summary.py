"""Summary-table renderer for telemetry JSONL logs.

One markdown table per log: each round stream's first/last/min/max/mean,
plus the run's summary facts — the same renderer CI appends to
``$GITHUB_STEP_SUMMARY`` (next to the bench delta table) and the
dashboard example reuses.

  PYTHONPATH=src python -m repro.telemetry.summary telemetry.jsonl
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.telemetry.events import read_events, streams_from_events


def _fmt(v) -> str:
    if isinstance(v, float) and not np.isfinite(v):
        return "nan"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _scalarize(row) -> float:
    """One representative scalar per stream row: vector streams (per-
    cluster consensus, staleness histogram) report their sum."""
    arr = np.asarray(row, dtype=np.float64)
    return float(arr) if arr.ndim == 0 else float(arr.sum())


def summary_table(events: list[dict]) -> str:
    meta = next((e for e in events if e.get("event") == "run_meta"), None)
    if meta is None:   # serve-only logs carry serve_meta instead
        meta = next((e for e in events if e.get("event") == "serve_meta"),
                    {})
    summary = next((e for e in events if e.get("event") == "summary"), {})
    serve = next((e for e in events if e.get("event") == "serve_summary"),
                 None)
    streams = streams_from_events(events)
    title = meta.get("method") or meta.get("arch") or "run"
    lines = [f"## telemetry — {title}", ""]
    facts = []
    for k in ("rounds", "n_clients", "n_clusters", "seed"):
        if k in meta:
            facts.append(f"{k}={meta[k]}")
    for k in ("mean_acc", "final_loss", "comm_bytes", "wire_bytes",
              "wall_s", "n_compiles", "n_dispatches"):
        if k in summary:
            facts.append(f"{k}={_fmt(summary[k])}")
    if facts:
        lines += [" · ".join(facts), ""]
    if streams:
        lines += [
            "| stream | first | last | min | max | mean |",
            "|---|---:|---:|---:|---:|---:|",
        ]
        for name in sorted(streams):
            per_round = np.asarray(
                [_scalarize(row) for row in streams[name]])
            with np.errstate(invalid="ignore"):
                lines.append(
                    f"| {name} | {_fmt(per_round[0])} "
                    f"| {_fmt(per_round[-1])} "
                    f"| {_fmt(float(np.nanmin(per_round)))} "
                    f"| {_fmt(float(np.nanmax(per_round)))} "
                    f"| {_fmt(float(np.nanmean(per_round)))} |"
                )
        lines.append("")
    if serve is not None:
        lines += [
            "| serve | requests | qps | p50 ms | p95 ms | p99 ms "
            "| dispatches | dequant |",
            "|---|---:|---:|---:|---:|---:|---:|---:|",
            f"| {meta.get('codec', '?')} | {serve.get('requests', 0)} "
            f"| {_fmt(serve.get('qps', 0.0))} "
            f"| {_fmt(serve.get('p50_ms', float('nan')))} "
            f"| {_fmt(serve.get('p95_ms', float('nan')))} "
            f"| {_fmt(serve.get('p99_ms', float('nan')))} "
            f"| {serve.get('n_dispatches', 0)} "
            f"| {serve.get('dequant_calls', 0)} |",
            "",
        ]
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", help="telemetry JSONL file(s)")
    args = ap.parse_args(argv)
    for path in args.paths:
        print(summary_table(read_events(path)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
