"""jax.profiler hooks: Perfetto-loadable traces of train and serve.

``trace_session(dir)`` wraps a whole run in ``jax.profiler.start_trace``
/ ``stop_trace`` (a no-op context when ``dir`` is falsy — the
``--profile-dir`` gate in both launchers).  ``annotate``/``step_annotation``
mark HOST-side regions (round dispatches, serve batches) on the trace
timeline; traced-code regions (the gossip mix, the serve-side plane
contraction) are labelled with ``jax.named_scope`` at their definition
sites instead, since host annotations cannot see inside a compiled
program.

Open the result at https://ui.perfetto.dev (or
``tensorboard --logdir <dir>``): the ``.trace.json.gz`` under
``<dir>/plugins/profile/<run>/`` loads directly.

Everything degrades to a no-op when the profiler API is unavailable —
telemetry must never fail a run.
"""
from __future__ import annotations

import contextlib


@contextlib.contextmanager
def trace_session(profile_dir=None):
    """Profile the enclosed block into ``profile_dir`` (no-op when None)."""
    if not profile_dir:
        yield
        return
    import jax

    jax.profiler.start_trace(str(profile_dir))
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def annotate(name: str):
    """Named host-span context (TraceAnnotation); no-op off-trace."""
    try:
        import jax

        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


def step_annotation(name: str, step: int):
    """Host-span carrying a step number (StepTraceAnnotation) — the
    profiler's per-step lane groups round dispatches by it."""
    try:
        import jax

        return jax.profiler.StepTraceAnnotation(name, step_num=int(step))
    except Exception:
        return contextlib.nullcontext()
