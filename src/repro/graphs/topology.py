"""Client communication topologies for decentralized FL.

The paper evaluates FedSPD on Erdős–Rényi (ER) random graphs, Barabási–Albert
(BA) preferential-attachment graphs, and Random Geometric Graphs (RGG), both
static and dynamically rewired (Appendix B.2.4). We implement all of them
host-side with numpy — topology is experiment configuration, not traced
computation — plus a pod-aware topology for the multi-pod production mesh
(dense intra-pod ICI, sparse inter-pod DCN bridges).

All generators guarantee a *connected* graph (the paper's convergence theorem
requires connectivity through Assumption 5.7) by retrying / augmenting with a
random spanning structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected client graph. ``adj`` is the augmented adjacency matrix
    (diagonal = 1, as in the paper's Table 1) over N clients."""

    adj: np.ndarray  # (N, N) float32, symmetric, diag == 1

    @property
    def n(self) -> int:
        return self.adj.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """Open-neighborhood degrees."""
        return self.adj.sum(axis=1) - 1.0

    @property
    def avg_degree(self) -> float:
        return float(self.degrees.mean())

    def neighbors(self, i: int) -> np.ndarray:
        nbrs = np.nonzero(self.adj[i])[0]
        return nbrs[nbrs != i]

    def edges(self) -> list[tuple[int, int]]:
        iu, ju = np.triu_indices(self.n, k=1)
        mask = self.adj[iu, ju] > 0
        return list(zip(iu[mask].tolist(), ju[mask].tolist()))

    def is_connected(self) -> bool:
        return _is_connected(self.adj)


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        v = stack.pop()
        for u in np.nonzero(adj[v])[0]:
            if not seen[u]:
                seen[u] = True
                stack.append(int(u))
    return bool(seen.all())


def _augment(adj: np.ndarray) -> np.ndarray:
    adj = adj.astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 1.0)
    return adj


def _connect_components(adj: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Add random edges between components until connected."""
    n = adj.shape[0]
    while not _is_connected(adj):
        # find a component and wire it to the rest
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            v = stack.pop()
            for u in np.nonzero(adj[v])[0]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(int(u))
        inside = np.nonzero(seen)[0]
        outside = np.nonzero(~seen)[0]
        i = rng.choice(inside)
        j = rng.choice(outside)
        adj[i, j] = adj[j, i] = 1.0
    return adj


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """Connected ER graph with link probability ``p`` (paper default)."""
    rng = np.random.default_rng(seed)
    u = rng.random((n, n))
    # mask AFTER thresholding: np.triu(u)<p would turn every zeroed
    # lower-triangle entry into an edge (0 < p), yielding a complete graph
    adj = np.triu((u < p).astype(np.float32), k=1)
    adj = _connect_components(_augment(adj), rng)
    return Graph(_augment(adj))


def barabasi_albert(n: int, m: int, seed: int = 0) -> Graph:
    """BA preferential attachment with ``m`` edges per new node."""
    rng = np.random.default_rng(seed)
    m = max(1, min(m, n - 1))
    adj = np.zeros((n, n), dtype=np.float32)
    # seed clique of m+1 nodes
    for i in range(m + 1):
        for j in range(i + 1, m + 1):
            adj[i, j] = adj[j, i] = 1.0
    deg = adj.sum(axis=1)
    for v in range(m + 1, n):
        probs = deg[:v] / deg[:v].sum()
        targets = rng.choice(v, size=m, replace=False, p=probs)
        for t in targets:
            adj[v, t] = adj[t, v] = 1.0
        deg = adj.sum(axis=1)
    adj = _connect_components(adj, rng)
    return Graph(_augment(adj))


def random_geometric(n: int, radius: float, seed: int = 0) -> Graph:
    """RGG on the unit square; edge iff distance < radius."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    d = np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1)
    adj = (d < radius).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    adj = _connect_components(_augment(adj), rng)
    return Graph(_augment(adj))


def ring(n: int) -> Graph:
    adj = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = 1.0
    return Graph(_augment(adj))


def complete(n: int) -> Graph:
    return Graph(_augment(np.ones((n, n), dtype=np.float32)))


def pod_aware(
    n_per_pod: int,
    n_pods: int,
    intra_p: float = 0.4,
    bridges_per_pod_pair: int = 2,
    seed: int = 0,
) -> Graph:
    """Production topology: dense ER within each pod (ICI), a few bridge
    edges between pods (DCN). Models the paper's low-connectivity regime at
    the pod boundary — exactly where FedSPD is claimed to shine."""
    rng = np.random.default_rng(seed)
    n = n_per_pod * n_pods
    adj = np.zeros((n, n), dtype=np.float32)
    for p in range(n_pods):
        lo = p * n_per_pod
        sub = erdos_renyi(n_per_pod, intra_p, seed=seed + 17 * p).adj
        adj[lo : lo + n_per_pod, lo : lo + n_per_pod] = sub
    for a in range(n_pods):
        for b in range(a + 1, n_pods):
            for _ in range(bridges_per_pod_pair):
                i = a * n_per_pod + rng.integers(n_per_pod)
                j = b * n_per_pod + rng.integers(n_per_pod)
                adj[i, j] = adj[j, i] = 1.0
    adj = _connect_components(adj, rng)
    return Graph(_augment(adj))


def rewire(graph: Graph, p_remove: float, seed: int = 0) -> Graph:
    """Dynamic topology (Appendix B.2.4): each existing edge is removed with
    probability ``p_remove``; new edges are added to keep the expected
    average degree roughly constant, and connectivity is repaired."""
    rng = np.random.default_rng(seed)
    n = graph.n
    adj = graph.adj.copy()
    np.fill_diagonal(adj, 0.0)
    edges = graph.edges()
    removed = 0
    for (i, j) in edges:
        if rng.random() < p_remove:
            adj[i, j] = adj[j, i] = 0.0
            removed += 1
    # add the same number of random non-edges back (keeps avg degree ~const)
    added = 0
    attempts = 0
    while added < removed and attempts < 50 * max(removed, 1):
        attempts += 1
        i, j = rng.integers(n), rng.integers(n)
        if i != j and adj[i, j] == 0:
            adj[i, j] = adj[j, i] = 1.0
            added += 1
    adj = _connect_components(_augment(adj), rng)
    return Graph(_augment(adj))


def union_graph(adjs: np.ndarray) -> Graph:
    """The union over a stack of adjacencies (leading axis: rounds or
    seeds). Static per-edge machinery — permute/ppermute edge colorings,
    the shard_map collective schedule — is built from the union so it
    covers every edge any stacked matrix can activate."""
    return Graph(_augment(np.asarray(adjs).max(axis=0)))


@dataclasses.dataclass(frozen=True)
class GraphSchedule:
    """A per-round sequence of client graphs (Appendix B.2.4's dynamic
    topologies). ``adjs`` stacks the augmented adjacencies (rounds, N, N);
    the round step consumes one (N, N) slice per round as a TRACED input
    (core/fedspd.make_round_step), so the whole schedule runs inside one
    jit compile."""

    adjs: np.ndarray  # (rounds, N, N) float32, each symmetric, diag == 1

    @property
    def rounds(self) -> int:
        return self.adjs.shape[0]

    @property
    def n(self) -> int:
        return self.adjs.shape[1]

    def graph(self, t: int) -> Graph:
        return Graph(self.adjs[t % self.rounds])

    def union(self) -> Graph:
        """The union graph over every scheduled round; each round's traced
        adjacency then masks the inactive edges (see ``union_graph``)."""
        return union_graph(self.adjs)


def stack_schedule(adjs: np.ndarray, rounds: int) -> np.ndarray:
    """Cycle/crop a stacked schedule to exactly ``rounds`` (rounds, N, N)
    matrices — the scan xs / per-round traced slices the experiment
    driver consumes. Shorter schedules cycle (a schedule is a topology
    PROCESS, not a fixed-length tape); longer ones are cropped."""
    adjs = np.asarray(adjs, dtype=np.float32)
    if adjs.ndim != 3 or adjs.shape[1] != adjs.shape[2]:
        raise ValueError(
            f"graph_schedule must stack (rounds, N, N) adjacencies; "
            f"got shape {adjs.shape}"
        )
    reps = -(-rounds // adjs.shape[0])
    return np.ascontiguousarray(np.tile(adjs, (reps, 1, 1))[:rounds])


def rewire_schedule(
    kind: str, n: int, avg_degree: float, rounds: int,
    p_rewire: float = 0.3, seed: int = 0,
) -> GraphSchedule:
    """Dynamically rewired ER/BA/RGG topologies (Appendix B.2.4): round 0 is
    ``make_graph(kind, ...)``; every following round rewires the previous
    graph (each edge removed with prob ``p_rewire``, replaced by random
    non-edges, connectivity repaired) — a Markov chain of connected graphs
    with roughly constant average degree."""
    g = make_graph(kind, n, avg_degree, seed=seed)
    adjs = [g.adj]
    for t in range(1, rounds):
        g = rewire(g, p_rewire, seed=seed + 1000003 * t)
        adjs.append(g.adj)
    return GraphSchedule(np.stack(adjs).astype(np.float32))


def symmetric_mask_drop(adj, u, p_drop: float, xp=np):
    """The ONE symmetric edge-drop core shared by the host path
    (``drop_edges`` below) and the traced path
    (experiments/scenarios.bernoulli_drop): ``u`` is an (N, N) symmetric
    matrix of per-edge uniforms (upper triangle drawn once, mirrored —
    failures are symmetric), each off-diagonal link drops where
    ``u < p_drop``, and the diagonal is kept (a client always keeps its
    own model). ``xp`` selects the array namespace (numpy / jax.numpy),
    so the two callers cannot drift."""
    n = adj.shape[-1]
    keep = (u >= p_drop).astype(adj.dtype)
    return adj * xp.maximum(keep, xp.eye(n, dtype=adj.dtype))


def drop_edges(adj: np.ndarray, p_drop: float,
               rng: np.random.Generator) -> np.ndarray:
    """One round of Bernoulli link failures: each undirected off-diagonal
    edge drops with prob ``p_drop`` (sampled once per edge — failures are
    symmetric), diagonal kept (a client always keeps its own model). No
    connectivity repair: dropout models per-round failures, not topology
    design (DeceFL-style robustness stress)."""
    adj = _augment(adj.copy())
    n = adj.shape[0]
    u = np.triu(rng.random((n, n)).astype(np.float32), k=1)
    u = u + u.T
    return symmetric_mask_drop(adj, u, p_drop, xp=np)


def dropout_schedule(
    graph: Graph, rounds: int, p_drop: float, seed: int = 0,
) -> GraphSchedule:
    """Per-round Bernoulli edge-dropout masks over a static base graph.
    Dropped links carry no traffic: the round step row-renormalizes the
    masked adjacency into the mixing matrix and the comm accounting
    charges only surviving links (zero wire bytes for a dropped edge)."""
    rng = np.random.default_rng(seed)
    adjs = np.stack([drop_edges(graph.adj, p_drop, rng)
                     for _ in range(rounds)])
    return GraphSchedule(adjs.astype(np.float32))


def make_graph(kind: str, n: int, avg_degree: float, seed: int = 0) -> Graph:
    """Uniform factory used by configs/benchmarks: target an average degree."""
    if kind == "er":
        p = min(1.0, avg_degree / max(n - 1, 1))
        return erdos_renyi(n, p, seed)
    if kind == "ba":
        return barabasi_albert(n, max(1, int(round(avg_degree / 2))), seed)
    if kind == "rgg":
        # E[deg] ~ n * pi * r^2 on unit square (ignoring edge effects)
        r = float(np.sqrt(avg_degree / (np.pi * max(n, 2))))
        return random_geometric(n, r, seed)
    if kind == "ring":
        return ring(n)
    if kind == "complete":
        return complete(n)
    raise ValueError(f"unknown graph kind: {kind}")
