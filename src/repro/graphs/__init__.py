from repro.graphs.coloring import (  # noqa: F401
    greedy_edge_coloring,
    matching_to_permutation,
    permute_schedule,
    schedule_stats,
    validate_coloring,
)
from repro.graphs.mixing import (  # noqa: F401
    consensus_rate_p,
    expected_fedspd_consensus_rate,
    metropolis_weights,
    spectral_gap,
    uniform_neighbor_weights,
)
from repro.graphs.topology import (  # noqa: F401
    Graph,
    barabasi_albert,
    complete,
    erdos_renyi,
    make_graph,
    pod_aware,
    random_geometric,
    rewire,
    ring,
)
