"""Mixing (weight) matrices and consensus-rate estimation.

FedSPD's cluster-center update (paper Eq. (1)) averages over the closed
neighborhood *restricted to clients that selected the same cluster this
round*; the resulting W_s^t is row-stochastic but data-dependent. We build it
on-device inside core/gossip.py. This module provides the *static* pieces:

- classical doubly-stochastic gossip matrices (Metropolis–Hastings, uniform)
  used by the decentralized baselines (FedAvg/FedEM/IFCA/... all gossip with
  a fixed W);
- spectral-gap estimation, which lower-bounds the paper's expected consensus
  rate ``p`` of Assumption 5.7 (E||C W - C̄||² ≤ (1-p)||C - C̄||²; for a
  static doubly-stochastic W, p = 1 - λ₂(WᵀW)).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.topology import Graph


def metropolis_weights(graph: Graph) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic.

    W_ij = 1 / (1 + max(d_i, d_j)) for edges, diagonal absorbs the rest.
    Doubly-stochastic W preserves the parameter average (paper Lemma A.1).
    """
    n = graph.n
    deg = graph.degrees
    w = np.zeros((n, n), dtype=np.float64)
    for i, j in graph.edges():
        w[i, j] = w[j, i] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w.astype(np.float32)


def uniform_neighbor_weights(graph: Graph) -> np.ndarray:
    """Row-stochastic closed-neighborhood averaging: W = A_aug / rowsum.

    This is FedSPD Eq. (1) in the degenerate case where *every* neighbor
    selected the same cluster. Not doubly stochastic in general.
    """
    adj = graph.adj
    return (adj / adj.sum(axis=1, keepdims=True)).astype(np.float32)


def spectral_gap(w: np.ndarray) -> float:
    """1 - |λ₂(W)|: the classical measure of gossip mixing speed."""
    ev = np.linalg.eigvals(w.astype(np.float64))
    mags = np.sort(np.abs(ev))[::-1]
    return float(1.0 - (mags[1] if len(mags) > 1 else 0.0))


def consensus_rate_p(w: np.ndarray) -> float:
    """The constant p of Assumption 5.7 for a static W (β=1):
    ||C W - C̄||_F² ≤ (1-p) ||C - C̄||_F² with p = 1 - σ₂(W)² where σ₂ is the
    second-largest singular value of the doubly-stochastic W."""
    sv = np.linalg.svd(w.astype(np.float64), compute_uv=False)
    s2 = sv[1] if len(sv) > 1 else 0.0
    return float(max(0.0, min(1.0, 1.0 - s2 * s2)))


def expected_fedspd_consensus_rate(
    graph: Graph, selection_probs: np.ndarray, n_rounds: int = 64, seed: int = 0
) -> float:
    """Monte-Carlo estimate of the paper's Assumption-5.7 constant for the
    *data-dependent* FedSPD mixing process of one cluster.

    Per round, each client selects the cluster with prob u_{i,s}; only
    selecting clients mix (closed neighborhood ∩ same selection). We measure
    the per-round Frobenius contraction of a random C toward its mean and
    report the empirical worst-case rate. Host-side diagnostic (numpy).
    """
    rng = np.random.default_rng(seed)
    n = graph.n
    worst = 1.0
    for _ in range(n_rounds):
        sel = rng.random(n) < selection_probs  # clients updating this cluster
        w = np.eye(n, dtype=np.float64)
        for i in range(n):
            if not sel[i]:
                continue
            nbrs = [j for j in graph.neighbors(i) if sel[j]] + [i]
            w[i, :] = 0.0
            w[i, nbrs] = 1.0 / len(nbrs)
        c = rng.standard_normal((n, 16))
        cb = c.mean(axis=0, keepdims=True)
        num = np.linalg.norm(w @ c - (w @ c).mean(axis=0, keepdims=True)) ** 2
        den = np.linalg.norm(c - cb) ** 2
        worst = min(worst, 1.0 - num / den) if den > 0 else worst
    return float(max(0.0, worst))
