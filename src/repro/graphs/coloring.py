"""Greedy edge coloring → collective_permute schedule.

The beyond-paper gossip path replaces the dense ``einsum(W, C)`` (which XLA
lowers to an all-gather along the client axis, bytes ∝ N·X) with one
``collective_permute`` per *color class* of the client graph's edges. A
proper edge coloring partitions edges into matchings; each matching is a
(partial) permutation that an ICI collective_permute can execute in one shot.
By Vizing's theorem a simple graph needs at most Δ+1 colors, so the schedule
moves ≈ deg·X bytes per client instead of N·X.

Everything here is host-side numpy over the static topology; the resulting
permutation lists are baked into the jitted gossip step.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.topology import Graph


def greedy_edge_coloring(graph: Graph) -> list[list[tuple[int, int]]]:
    """Partition edges into matchings (color classes), largest first.

    Greedy: process edges in descending (deg_i + deg_j) order; assign each to
    the first class where neither endpoint is used. Uses ≤ 2Δ-1 classes in
    the worst case, Δ..Δ+1 in practice for the sparse graphs we use.
    """
    deg = graph.degrees
    edges = sorted(graph.edges(), key=lambda e: -(deg[e[0]] + deg[e[1]]))
    classes: list[list[tuple[int, int]]] = []
    used: list[set[int]] = []
    for (i, j) in edges:
        placed = False
        for cls, busy in zip(classes, used):
            if i not in busy and j not in busy:
                cls.append((i, j))
                busy.add(i)
                busy.add(j)
                placed = True
                break
        if not placed:
            classes.append([(i, j)])
            used.append({i, j})
    return classes


def matching_to_permutation(matching: list[tuple[int, int]], n: int) -> np.ndarray:
    """A matching as a self-inverse permutation array: perm[i] = partner or i.

    collective_permute with (src, dst) pairs (i→j and j→i) realizes a full
    swap of the matched endpoints; unmatched clients send to themselves
    (identity lanes carry no inter-chip traffic after XLA simplification,
    but we keep them so the permutation is total).
    """
    perm = np.arange(n)
    for (i, j) in matching:
        perm[i], perm[j] = j, i
    return perm


def permute_schedule(graph: Graph) -> list[np.ndarray]:
    """The full gossip schedule: one permutation per color class."""
    return [
        matching_to_permutation(m, graph.n) for m in greedy_edge_coloring(graph)
    ]


def schedule_stats(graph: Graph) -> dict:
    classes = greedy_edge_coloring(graph)
    return {
        "n_colors": len(classes),
        "n_edges": len(graph.edges()),
        "max_degree": int(graph.degrees.max()),
        "bytes_ratio_vs_allgather": len(classes) / max(graph.n - 1, 1),
    }


def validate_coloring(graph: Graph) -> bool:
    """Every edge appears exactly once; classes are matchings."""
    classes = greedy_edge_coloring(graph)
    seen = set()
    for cls in classes:
        endpoints: set[int] = set()
        for (i, j) in cls:
            e = (min(i, j), max(i, j))
            if e in seen or i in endpoints or j in endpoints:
                return False
            seen.add(e)
            endpoints.update((i, j))
    return seen == {(min(i, j), max(i, j)) for (i, j) in graph.edges()}
