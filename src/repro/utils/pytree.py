"""Pytree utilities shared across the framework.

Everything here is shape-polymorphic and jit-safe unless noted. FedSPD
treats models as opaque pytrees; these helpers implement the linear-algebra
view of a pytree (flatten to a vector, weighted sums, norms) that the
paper's matrix notation (C_s in R^{N x X}) relies on.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_map(f: Callable, *trees: PyTree) -> PyTree:
    return jax.tree.map(f, *trees)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, alpha) -> PyTree:
    return jax.tree.map(lambda x: x * alpha, tree)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_weighted_sum(trees: PyTree, weights: jax.Array) -> PyTree:
    """Weighted sum over the *leading* axis of every leaf.

    ``trees`` leaves have shape (K, ...); ``weights`` has shape (K,).
    Used for the final personalization x_i = sum_s u_{i,s} c_{i,s} (Eq. 2).
    """
    def ws(leaf):
        w = weights.astype(leaf.dtype).reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(w * leaf, axis=0)

    return jax.tree.map(ws, trees)


def tree_vdot(a: PyTree, b: PyTree) -> jax.Array:
    parts = jax.tree.map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b
    )
    return functools.reduce(jnp.add, jax.tree.leaves(parts))


def tree_sq_norm(tree: PyTree) -> jax.Array:
    return tree_vdot(tree, tree)


def tree_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(tree))


def tree_cosine_similarity(a: PyTree, b: PyTree, eps: float = 1e-12) -> jax.Array:
    """Cosine similarity between two parameter pytrees (flattened view).

    The paper uses cosine similarity of received model parameters to resolve
    label switching across clients (Section 6, "Client communications").
    """
    return tree_vdot(a, b) / (tree_norm(a) * tree_norm(b) + eps)


def tree_size(tree: PyTree) -> int:
    """Total number of scalars — static (host int)."""
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(
        sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree))
    )


def tree_ravel(tree: PyTree) -> jax.Array:
    """Flatten a pytree into a single fp32 vector (jit-safe)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_stack(trees: list) -> PyTree:
    """Stack a python list of identically-structured pytrees on axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(tree: PyTree, idx) -> PyTree:
    """Index the leading axis of every leaf."""
    return jax.tree.map(lambda x: x[idx], tree)


def tree_dynamic_index(tree: PyTree, idx: jax.Array) -> PyTree:
    """Traced index into the leading axis of every leaf."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def tree_dynamic_update(tree: PyTree, idx: jax.Array, value: PyTree) -> PyTree:
    """Scatter ``value`` into the leading axis at traced index ``idx``."""
    return jax.tree.map(lambda x, v: x.at[idx].set(v.astype(x.dtype)), tree, value)


def global_shape_summary(tree: PyTree) -> dict:
    """Host-side structural summary (for DESIGN/EXPERIMENTS reporting)."""
    return {
        "num_params": tree_size(tree),
        "num_bytes": tree_bytes(tree),
        "num_leaves": len(jax.tree.leaves(tree)),
    }
