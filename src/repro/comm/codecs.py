"""Compressed-communication codecs for the packed parameter plane.

FedSPD's second headline claim is that selective, cluster-wise exchange
"substantially reduces communication costs"; DisPFL-style systems push the
same lever further with sparse/quantized payloads. This module is the wire
layer for every method in the registry: a codec turns the (N, X) /
(S, N, X) plane slice a round is about to exchange into an encoded payload
(what actually crosses an edge), and back into the dequantized values the
receivers mix. Because PR 3 made the packed plane universal, one
implementation on flat slices serves all 13 method ids.

Codecs (``CommConfig.codec``):

- ``fp32``  passthrough — the uncompressed baseline. By construction this
  is a bit-exact no-op: ``make_channel`` returns ``None`` and every call
  site keeps its original, unmodified code path (asserted in tests).
- ``int8`` / ``int4``  stochastic uniform quantization with per-block
  scales: each ``block``-wide slice of the X axis is scaled by
  ``max|x| / qmax`` and rounded stochastically (``floor(y + u)``,
  u ~ U[0,1)), which makes the codec UNBIASED: E[decode(encode(x))] = x.
  Wire cost: int8 ships one byte per value + fp32 scales
  (``X + 4·ceil(X/block)``); int4 ships REAL paired nibbles in uint8 +
  fp16 scales (``ceil(X/2) + 2·ceil(X/block)``). The int4 device payload
  keeps int8 storage in [-7, 7] — compute reads it unpacked — but the
  serialized wire/disk image (``Channel.serialize_payload``) is the
  bit-packed form, and its byte length equals ``wire_model_bytes``
  EXACTLY. int4 scales are rounded through fp16 at encode time so the
  device decode and the wire decode are bit-identical.
- ``topk``  magnitude sparsification: the k largest-|x| entries of each
  (X,)-message survive as (value, index) pairs; 8k bytes per message.
  Top-k is BIASED — pair it with ``error_feedback=True`` so the dropped
  mass re-enters the stream next round instead of being lost.

Error feedback (Karimireddy et al. 2019): the channel carries a per-client
residual e; each round transmits encode(x + e) and keeps
e' = (x + e) − decode(encode(x + e)). The residual lives in the method's
round-loop state (an ``ef`` field on the state NamedTuples), so it rides
vmap/donation like every other state leaf.

All codecs operate on arrays whose LAST axis is the flat message width X
and are shape-polymorphic over leading batch prefixes — the same channel
encodes a (N, X) selected-center slab and FedEM's full (S, N, X) stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

CODECS = ("fp32", "int8", "int4", "topk")


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Per-run communication-compression knob (``run_method(comm=...)``).

    ``block`` is the quantization-scale granularity along X (one fp32
    scale per block). ``k`` is the survivors-per-message count for
    ``topk`` (default: X // 16). ``error_feedback`` threads the residual
    state through the round loop."""

    codec: str = "fp32"
    block: int = 256
    k: Optional[int] = None
    error_feedback: bool = False

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(
                f"unknown codec {self.codec!r}; expected one of {CODECS}"
            )
        if self.block <= 0:
            raise ValueError(f"block must be positive, got {self.block}")
        if self.k is not None and self.k <= 0:
            raise ValueError(f"k must be positive, got {self.k}")


def available_codecs() -> tuple[str, ...]:
    return CODECS


# --------------------------------------------------------------------------
# Quantization: stochastic uniform with per-block scales
# --------------------------------------------------------------------------


def _quant_bits(codec: str) -> int:
    return {"int8": 8, "int4": 4}[codec]


def _pad_width(x_width: int, block: int) -> tuple[int, int]:
    nq = -(-x_width // block)
    return nq, nq * block


def quant_encode(x: jnp.ndarray, key: jax.Array, *, bits: int,
                 block: int, scale_dtype=jnp.float32,
                 rounding: str = "stochastic") -> dict:
    """x (..., X) -> {"q": (..., Xp) int8, "scale": (..., Xp/block) f32}.

    Xp pads X up to a whole number of scale blocks; the padded tail
    quantizes to exact zeros, so the fused dequantize+mix kernel can run
    on the padded width with no edge special-casing and the caller crops
    the output back to X.

    ``scale_dtype`` rounds the per-block scales through a narrower wire
    dtype (int4 ships fp16 scales) BEFORE the division, so quantizing and
    dequantizing with the stored scale keeps the one-step error bound and
    the device stream is bit-identical to what a receiver reconstructs
    from the serialized payload. ``rounding="nearest"`` (u = 1/2,
    ``key`` may be None) is the deterministic variant used when shipping
    a plane once — e.g. a servable artifact — where unbiasedness across
    repeated sends buys nothing and halving the worst-case error does."""
    x_width = x.shape[-1]
    nq, xp = _pad_width(x_width, block)
    qmax = float(2 ** (bits - 1) - 1)
    xb = jnp.pad(
        x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, xp - x_width)]
    ).reshape(x.shape[:-1] + (nq, block))
    scale = jnp.max(jnp.abs(xb), axis=-1) / qmax          # (..., nq)
    if jnp.dtype(scale_dtype) != jnp.float32:
        scale = scale.astype(scale_dtype).astype(jnp.float32)
    y = xb / jnp.maximum(scale, 1e-12)[..., None]          # |y| <= qmax
    if rounding == "nearest":
        u = 0.5
    elif rounding == "stochastic":
        u = jax.random.uniform(key, xb.shape, jnp.float32)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    q = jnp.clip(jnp.floor(y + u), -qmax, qmax).astype(jnp.int8)
    return {"q": q.reshape(x.shape[:-1] + (xp,)), "scale": scale}


def quant_decode(enc: dict, *, block: int, x_width: int) -> jnp.ndarray:
    q, scale = enc["q"], enc["scale"]
    xb = q.reshape(q.shape[:-1] + (scale.shape[-1], block))
    out = xb.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
    return out.reshape(q.shape)[..., :x_width]


# --------------------------------------------------------------------------
# int4 bit packing: paired two's-complement nibbles in uint8
# --------------------------------------------------------------------------


def int4_pack(q: jnp.ndarray) -> jnp.ndarray:
    """(..., W) int8 values in [-8, 7] -> (..., ceil(W/2)) uint8.

    Adjacent pairs along the last axis share one byte: element 2i in the
    low nibble, 2i+1 in the high nibble, both as two's-complement 4-bit
    values. Odd widths pad one zero nibble (the wire format's
    ``ceil(X/2)``). Works identically as host numpy or traced jnp."""
    w = q.shape[-1]
    if w % 2:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
    lo = q[..., 0::2].astype(jnp.uint8) & 0xF
    hi = q[..., 1::2].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def int4_unpack(packed: jnp.ndarray, width: int) -> jnp.ndarray:
    """Inverse of ``int4_pack``: (..., ceil(W/2)) uint8 -> (..., W) int8.

    Bit-exact: ``int4_unpack(int4_pack(q), q.shape[-1]) == q`` for every
    int8 ``q`` in [-8, 7] (asserted in tests/test_comm.py)."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    v = jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[:-1] + (2 * packed.shape[-1],)
    )
    v = v - jnp.asarray(16, jnp.int8) * (v > 7).astype(jnp.int8)
    return v[..., :width]


# --------------------------------------------------------------------------
# Top-k magnitude sparsification
# --------------------------------------------------------------------------


def topk_encode(x: jnp.ndarray, k: int) -> dict:
    """x (..., X) -> {"v": (..., k) f32, "i": (..., k) int32}."""
    _, idx = jax.lax.top_k(jnp.abs(x.astype(jnp.float32)), k)
    vals = jnp.take_along_axis(x.astype(jnp.float32), idx, axis=-1)
    return {"v": vals, "i": idx.astype(jnp.int32)}


def topk_decode(enc: dict, *, x_width: int) -> jnp.ndarray:
    v, i = enc["v"], enc["i"]
    batch = v.shape[:-1]
    flat_v = v.reshape((-1, v.shape[-1]))
    flat_i = i.reshape((-1, i.shape[-1]))
    out = jax.vmap(
        lambda vv, ii: jnp.zeros((x_width,), jnp.float32).at[ii].set(vv)
    )(flat_v, flat_i)
    return out.reshape(batch + (x_width,))


# --------------------------------------------------------------------------
# Channel: a codec bound to a message width, plus error feedback
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Channel:
    """One codec bound to a flat message width X.

    ``fused`` marks codecs whose encoded payload (int8 values + per-block
    scales) the fused Pallas kernel (kernels/gossip_mix.gossip_mix_dequant)
    can consume directly — the mix then reads the COMPRESSED plane from
    HBM instead of a materialized fp32 decode. ``wire_model_bytes`` is the
    exact physical payload per single-model message; logical bytes (what
    the uncompressed exchange would have moved) stay with the original
    dtypes, so reported compression ratios are honest."""

    cfg: CommConfig
    x: int  # logical flat message width

    @property
    def has_ef(self) -> bool:
        return self.cfg.error_feedback

    @property
    def fused(self) -> bool:
        return self.cfg.codec in ("int8", "int4")

    @property
    def k(self) -> int:
        return self.cfg.k if self.cfg.k is not None else max(1, self.x // 16)

    @property
    def scale_wire_dtype(self):
        """Dtype the per-block scales ship in: fp16 for int4 (half the
        scale overhead of a codec whose whole point is halving bytes),
        fp32 for int8. Encode rounds through this dtype, so device and
        wire decodes agree bit for bit."""
        return np.float16 if self.cfg.codec == "int4" else np.float32

    @property
    def scale_bytes(self) -> int:
        """Per-message scale payload: one ``scale_wire_dtype`` scalar per
        quantization block."""
        nq, _ = _pad_width(self.x, self.cfg.block)
        return int(np.dtype(self.scale_wire_dtype).itemsize * nq)

    @property
    def wire_model_bytes(self) -> int:
        c = self.cfg
        if c.codec == "fp32":
            return 4 * self.x
        if c.codec == "int8":
            return int(self.x + self.scale_bytes)
        if c.codec == "int4":
            # paired nibbles: exactly what serialize_payload emits
            return int(-(-self.x // 2) + self.scale_bytes)
        return int(8 * min(self.k, self.x))  # topk: fp32 value + int32 index

    def wire_ratio(self, logical_model_bytes: int) -> float:
        """wire / logical bytes per message (exact, static per model)."""
        return self.wire_model_bytes / float(logical_model_bytes)

    # -------------------------------------------------- encode / decode

    def encode(self, x: jnp.ndarray, key: jax.Array, *,
               rounding: str = "stochastic") -> dict:
        c = self.cfg
        if c.codec in ("int8", "int4"):
            return quant_encode(x, key, bits=_quant_bits(c.codec),
                                block=c.block,
                                scale_dtype=self.scale_wire_dtype,
                                rounding=rounding)
        if c.codec == "topk":
            return topk_encode(x, min(self.k, self.x))
        raise ValueError(f"codec {c.codec!r} has no encoded form")

    def decode(self, enc: dict) -> jnp.ndarray:
        c = self.cfg
        if c.codec in ("int8", "int4"):
            return quant_decode(enc, block=c.block, x_width=self.x)
        return topk_decode(enc, x_width=self.x)

    # ------------------------------------------------- wire serialization

    def serialize_payload(self, enc: dict) -> bytes:
        """The exact physical wire/disk image of an encoded message batch:
        the quantized payload (int4: paired nibbles, int8: raw bytes)
        followed by the per-block scales in ``scale_wire_dtype``, both
        cropped to the LOGICAL width X (the encode-side pad is zeros the
        receiver reconstructs). ``len(...) == n_messages ×
        wire_model_bytes`` exactly — wire accounting is the serializer,
        not an estimate (asserted in tests/test_comm.py)."""
        c = self.cfg
        if c.codec not in ("int8", "int4"):
            raise ValueError(
                f"codec {c.codec!r} has no plane wire format (quantized "
                "codecs only)"
            )
        q = np.asarray(enc["q"])[..., : self.x]
        sc = np.ascontiguousarray(
            np.asarray(enc["scale"]), dtype=self.scale_wire_dtype
        )
        if c.codec == "int4":
            payload = np.asarray(int4_pack(jnp.asarray(q)))
        else:
            payload = q.astype(np.int8)
        return np.ascontiguousarray(payload).tobytes() + sc.tobytes()

    def deserialize_payload(self, data: bytes,
                            batch_prefix: tuple = ()) -> dict:
        """Inverse of ``serialize_payload`` for a ``batch_prefix``-shaped
        message batch: reconstructs the device-form encoding ({"q" int8
        padded to whole scale blocks, "scale" f32}) such that
        ``decode(deserialize(serialize(enc)))`` is bit-identical to
        ``decode(enc)``."""
        c = self.cfg
        nq, xp = _pad_width(self.x, c.block)
        batch = tuple(int(b) for b in batch_prefix)
        n_msgs = int(np.prod(batch)) if batch else 1
        if len(data) != n_msgs * self.wire_model_bytes:
            raise ValueError(
                f"payload is {len(data)} bytes; {batch} × "
                f"{self.cfg.codec} messages of width {self.x} need "
                f"{n_msgs * self.wire_model_bytes}"
            )
        qw = -(-self.x // 2) if c.codec == "int4" else self.x
        split = n_msgs * qw
        raw = np.frombuffer(data[:split], dtype=np.uint8).reshape(
            batch + (qw,))
        if c.codec == "int4":
            q = np.asarray(int4_unpack(jnp.asarray(raw), self.x))
        else:
            q = raw.view(np.int8)
        q = np.pad(q, [(0, 0)] * len(batch) + [(0, xp - self.x)])
        sc = np.frombuffer(data[split:], dtype=self.scale_wire_dtype)
        sc = sc.reshape(batch + (nq,)).astype(np.float32)
        return {"q": jnp.asarray(q), "scale": jnp.asarray(sc)}

    # ---------------------------------------------- round-loop interface

    def init_residual(self, batch_prefix: tuple) -> Optional[jnp.ndarray]:
        """Per-client error-feedback residual carried in the round loop —
        zeros of the full message shape, or None when EF is off (the state
        pytree then carries an empty subtree)."""
        if not self.has_ef:
            return None
        return jnp.zeros(tuple(batch_prefix) + (self.x,), jnp.float32)

    def encode_stream(self, x: jnp.ndarray, key: jax.Array,
                      ef: Optional[jnp.ndarray], *, need_hat: bool = False):
        """One channel use: returns (enc, x_hat_or_None, ef').

        ``x_hat`` (the receiver-side decode) is materialized only when the
        residual update or the caller (``need_hat``) demands it — the
        fused Pallas path without EF never decodes outside the kernel."""
        msg = x.astype(jnp.float32) + ef if ef is not None else x
        enc = self.encode(msg, key)
        x_hat = None
        if self.has_ef or need_hat:
            x_hat = self.decode(enc)
        if self.has_ef:
            ef = msg.astype(jnp.float32) - x_hat
        return enc, x_hat, ef

    def roundtrip(self, x: jnp.ndarray, key: jax.Array,
                  ef: Optional[jnp.ndarray]):
        """decode(encode(x + ef)) plus the residual update: what the
        receivers see, and what the sender keeps. Returns (x_hat, ef')."""
        enc, x_hat, ef = self.encode_stream(x, key, ef, need_hat=True)
        return x_hat, ef


def sparse_wire_model_bytes(cfg: Optional[CommConfig], x: int,
                            k_active: int) -> int:
    """Exact physical bytes per single-model SPARSE message: nnz payload
    plus support bitmap (core/sparse masks; DisPFL).

    The sparse wire format gathers the ``k_active`` active values into a
    compact run, encodes THAT (mask-then-encode: quantization blocks tile
    the compact run, so scales cover nnz — never dead air), and prepends a
    ``ceil(X/8)``-byte support bitmap the receiver scatters by. All terms
    are static given (codec, X, density), so accounting stays a
    trace-free per-message constant like ``Channel.wire_model_bytes``:

    - fp32: ``4·k + ceil(X/8)``
    - int8: ``k + 4·ceil(k/block) + ceil(X/8)``
    - int4: ``ceil(k/2) + 2·ceil(k/block) + ceil(X/8)``
    - topk: ``8·min(topk_k, k)`` — NO bitmap: the top-k payload already
      carries explicit (value, index) pairs, and survivors can only come
      from the active support, so masking never inflates the message

    For the density-scaling codecs (fp32/int8/int4) the result is bounded
    by ``density·dense_wire + bitmap`` (asserted in tests/test_sparse.py);
    topk is instead bounded by its own dense cost.
    """
    bitmap = -(-x // 8)
    if cfg is None or cfg.codec == "fp32":
        return int(4 * k_active + bitmap)
    if cfg.codec == "int8":
        return int(k_active + 4 * -(-k_active // cfg.block) + bitmap)
    if cfg.codec == "int4":
        return int(-(-k_active // 2) + 2 * -(-k_active // cfg.block)
                   + bitmap)
    k_top = cfg.k if cfg.k is not None else max(1, x // 16)
    return int(8 * min(k_top, k_active))


def make_channel(cfg: Optional[CommConfig], x_width: int) -> Optional[Channel]:
    """Channel for a flat message width — or ``None`` for no compression.

    ``codec="fp32"`` maps to ``None`` BY DESIGN: the uncompressed exchange
    must be the exact original code path (bit-exact no-op, no extra key
    splits, no residual state), so wire accounting for it is handled by
    the driver without a channel object."""
    if cfg is None or cfg.codec == "fp32":
        return None
    return Channel(cfg=cfg, x=int(x_width))


class WithEF(NamedTuple):
    """State rider for methods whose round-loop state is a bare array
    (FedAvg's packed plane): the error-feedback residual travels next to
    the payload through vmap/jit/donation like any other state leaf.
    Methods with NamedTuple states grow an ``ef`` field instead."""

    x: Any
    ef: Any


def split_ef(state, channel: Optional[Channel]):
    """(payload, residual) from a possibly-WithEF-wrapped state."""
    if channel is not None and channel.has_ef:
        return state.x, state.ef
    return state, None


def join_ef(x, ef, channel: Optional[Channel]):
    """Inverse of ``split_ef`` — wrap only when the channel carries EF, so
    non-EF runs keep their state pytree (and jit cache keys) unchanged."""
    if channel is not None and channel.has_ef:
        return WithEF(x, ef)
    return x


def exchange(channel: Optional[Channel], x: jnp.ndarray, mix,
             key: Optional[jax.Array], ef: Optional[jnp.ndarray]):
    """The reference compressed exchange: mix(decode(encode(x + ef))).

    ``mix`` is any callable on the decoded plane slice (a baseline's W·C
    average, FedSPD's Eq. (1), FedSoft's importance-weighted aggregate).
    With ``channel=None`` this is exactly ``mix(x)`` — the fp32 no-op.
    Returns (mixed, ef')."""
    if channel is None:
        return mix(x), ef
    x_hat, ef = channel.roundtrip(x, key, ef)
    return mix(x_hat), ef
