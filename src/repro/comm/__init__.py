"""Compressed-communication subsystem: codecs, channels, wire accounting.

One implementation on the packed parameter plane serves every method in
the registry — see comm/codecs.py for the codec table and
core/gossip.make_mix_fn(comm=...) for the execution paths.
"""
from repro.comm.codecs import (
    CODECS,
    Channel,
    CommConfig,
    WithEF,
    available_codecs,
    exchange,
    int4_pack,
    int4_unpack,
    join_ef,
    make_channel,
    quant_decode,
    quant_encode,
    split_ef,
    topk_decode,
    topk_encode,
)

__all__ = [
    "CODECS",
    "Channel",
    "CommConfig",
    "WithEF",
    "available_codecs",
    "exchange",
    "int4_pack",
    "int4_unpack",
    "join_ef",
    "make_channel",
    "split_ef",
    "quant_decode",
    "quant_encode",
    "topk_decode",
    "topk_encode",
]
