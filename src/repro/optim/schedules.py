"""Learning-rate schedules. The paper uses multiplicative decay per global
epoch (initial 5e-2, factor 0.80). We also provide cosine + warmup for the
LLM substrate."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(lr0: float, decay: float, steps_per_decay: int = 1) -> Schedule:
    """Paper-faithful: lr0 * decay^(epoch)."""

    def fn(step):
        e = jnp.asarray(step, jnp.float32) / steps_per_decay
        return jnp.asarray(lr0, jnp.float32) * jnp.power(decay, jnp.floor(e))

    return fn


def cosine_with_warmup(lr0: float, warmup: int, total: int, floor: float = 0.1) -> Schedule:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr0 * jnp.where(s < warmup, warm, cos)

    return fn


def make_schedule(name: str, **kw) -> Schedule:
    reg = {
        "constant": constant,
        "exponential": exponential_decay,
        "cosine": cosine_with_warmup,
    }
    if name not in reg:
        raise ValueError(f"unknown schedule {name!r}")
    return reg[name](**kw)
