from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_with_warmup,
    exponential_decay,
    make_schedule,
)
from repro.optim.sgd import (  # noqa: F401
    Optimizer,
    adamw,
    clip_by_global_norm,
    make_optimizer,
    momentum,
    sgd,
)
