"""Optimizers.

The paper trains every method with plain SGD and a decayed learning rate
(Appendix B.1: initial lr 5e-2, decay 0.80). We implement SGD (paper-
faithful), SGD-momentum, and AdamW (used for the LLM-scale substrate where
plain SGD would be an unrealistic production choice). All optimizers are
optax-style (init/update) but self-contained — no external deps.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def sgd() -> Optimizer:
    """Paper-faithful plain SGD: x ← x - η g. Stateless."""

    def init(params):
        return ()

    def update(grads, state, params, lr):
        # accumulate the step in fp32, cast ONCE at the end — bf16 param
        # stores must not be promoted (scan carries require stable dtypes)
        new = jax.tree.map(
            lambda p, g: (
                p.astype(jnp.float32) - lr * g.astype(jnp.float32)
            ).astype(p.dtype),
            params, grads,
        )
        return new, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype), state, grads)
        if nesterov:
            step = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype), new_m, grads)
        else:
            step = new_m
        new_p = jax.tree.map(
            lambda p, s: (
                p.astype(jnp.float32) - lr * s.astype(jnp.float32)
            ).astype(p.dtype),
            params, step,
        )
        return new_p, new_m

    return Optimizer(init, update)


def adamw(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    class AdamState(NamedTuple):
        mu: PyTree
        nu: PyTree
        count: jax.Array

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(mu=z, nu=jax.tree.map(jnp.zeros_like, z), count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state.count + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def step(p, m, v):
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_p = jax.tree.map(step, params, mu, nu)
        return new_p, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)


_REGISTRY = {"sgd": sgd, "momentum": momentum, "adamw": adamw}


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name not in _REGISTRY:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale.astype(g.dtype)), grads)
