"""Whisper-base [arXiv:2212.04356]: enc-dec, 6+6L, d_model 512, 8 heads,
d_ff 2048, vocab 51865. Conv/mel frontend STUBBED: input_specs() supplies
precomputed frame embeddings (B, 1500, 512)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    encoder_layers=6,
    encoder_frames=1500,
    encoder_d_model=512,
    norm="rmsnorm",
    act="gelu",
    citation="arXiv:2212.04356",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        encoder_layers=2, encoder_frames=64, encoder_d_model=128,
        param_dtype="float32", compute_dtype="float32",
    )
