"""H2O-Danube-1.8B [arXiv:2401.16818]: 24L, d_model 2560, 32 heads (GQA
kv=8), d_ff 6912, vocab 32000; llama+mistral mix with sliding-window
attention (window 4096)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    window=4096,  # SWA (mistral-style)
    norm="rmsnorm",
    act="silu",
    citation="arXiv:2401.16818",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        window=64, param_dtype="float32", compute_dtype="float32",
    )
