"""Mamba2-370m [arXiv:2405.21060]: 48 SSD layers, d_model 1024 (attn-free),
vocab 50280, ssm_state 128, headdim 64, expand 2."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    norm="rmsnorm",
    act="silu",
    citation="arXiv:2405.21060",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, vocab=512, ssm_state=16, ssm_headdim=32,
        param_dtype="float32", compute_dtype="float32",
    )
