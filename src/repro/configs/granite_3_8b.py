"""Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family, 8B shape]: 40L,
d_model 4096, 32 heads (GQA kv=8), d_ff 12800, vocab 49155."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    norm="rmsnorm",
    act="silu",
    param_dtype="bfloat16",  # 8B: bf16 param store (DESIGN.md §5)
    citation="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384, vocab=512,
        param_dtype="float32", compute_dtype="float32",
    )
