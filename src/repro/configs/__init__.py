from repro.configs.base import (  # noqa: F401
    ARCH_ALIASES,
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    get_config,
    get_smoke_config,
)
