"""Chameleon-34B [arXiv:2405.09818]: 48L, d_model 8192, 64 heads (GQA kv=8),
d_ff 22016, vocab 65536 (early-fusion: VQ image tokens share the text vocab;
the VQ-GAN codec frontend is STUBBED — inputs are token ids). Uses qk-norm
as in the paper."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
    param_dtype="bfloat16",  # 34B: bf16 param store (DESIGN.md §5)
    citation="arXiv:2405.09818",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=384, vocab=512,
        param_dtype="float32", compute_dtype="float32",
    )
