"""The paper's own experimental scale: small classifier heads over mixture
data. Not an assigned architecture — this is the config used by the
EXPERIMENTS.md §Accuracy reproduction runs (paper Tables 2–7 analogues)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperExpConfig:
    n_clients: int = 20
    n_clusters: int = 2
    model: str = "mlp"  # mlp | conv
    dim: int = 64
    n_classes: int = 10
    n_per_client: int = 256
    rounds: int = 60
    tau: int = 5  # local epochs per round (paper default 5)
    tau_final: int = 10
    lr0: float = 5e-2
    lr_decay: float = 0.98
    batch: int = 32
    graph_kind: str = "er"
    avg_degree: float = 5.0
    seed: int = 0
    mode: str = "rotate"  # data construction


DEFAULT = PaperExpConfig()
