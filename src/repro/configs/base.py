"""Architecture + run configuration schema.

Every assigned architecture gets one file in this package exporting ``CONFIG``
(an :class:`ArchConfig` with the exact published shape) and
``smoke_config()`` (a reduced same-family variant for CPU tests: ≤2 layers,
d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # slot-position computation in the capacity dispatch: "cumsum" is the
    # naive Switch formulation (an O(T·k × E) running sum that XLA lowers /
    # costs as a quadratic reduce-window — see EXPERIMENTS.md §Perf);
    # "sort" computes identical positions via stable argsort ranking.
    moe_dispatch: str = "cumsum"

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- hybrid (zamba2-style shared attention block) ---
    attn_every: int = 0  # insert the shared attn block after every k SSM layers

    # --- attention pattern ---
    window: Optional[int] = None  # sliding-window size (None = full causal)
    local_global_ratio: int = 0  # gemma3: k local layers per 1 global
    local_window: int = 1024
    rope_theta: float = 10000.0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 0  # stub frontend sequence length (whisper: 1500)
    encoder_d_model: int = 0

    # --- norms / misc ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm_np (non-parametric)
    act: str = "silu"  # silu (swiglu) | gelu (plain mlp)
    tie_embeddings: bool = False
    qk_norm: bool = False  # chameleon uses qk-norm

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- dry-run cost accounting (XLA's HloCostAnalysis counts a while-loop
    # body ONCE regardless of trip count; the dry-run unrolls the layer stack
    # and the attention pair scan so cost_analysis/collective parsing see the
    # true trip counts; 1 = rolled (runtime default), 0 = fully unrolled) ---
    scan_unroll: int = 1
    attn_unroll: int = 1
    attn_q_block: int = 512
    attn_kv_block: int = 512

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(
                self, "head_dim",
                self.d_model // self.n_heads if self.n_heads else 0,
            )
        assert self.n_heads == 0 or self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to a multiple of 256 so the embedding shards 16-way."""
        return _round_up(self.vocab, 256)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_decoder_only(self) -> bool:
        return self.encoder_layers == 0

    @property
    def supports_long_context(self) -> bool:
        """Natively sub-quadratic (SSM / hybrid / sliding-window)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.window is not None
            or self.local_global_ratio > 0
        )

    def param_dtype_jnp(self):
        return jnp.dtype(self.param_dtype)

    def compute_dtype_jnp(self):
        return jnp.dtype(self.compute_dtype)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


ASSIGNED_ARCHS = (
    "olmo-1b",
    "olmoe-1b-7b",
    "phi3_5-moe-42b-a6_6b",
    "whisper-base",
    "h2o-danube-1_8b",
    "zamba2-1_2b",
    "gemma3-1b",
    "granite-3-8b",
    "mamba2-370m",
    "chameleon-34b",
)

# CLI ids (with dots/dashes) -> module names
ARCH_ALIASES = {
    "olmo-1b": "olmo_1b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "phi3_5-moe-42b-a6_6b": "phi35_moe",
    "whisper-base": "whisper_base",
    "h2o-danube-1.8b": "h2o_danube",
    "h2o-danube-1_8b": "h2o_danube",
    "zamba2-1.2b": "zamba2_1_2b",
    "zamba2-1_2b": "zamba2_1_2b",
    "gemma3-1b": "gemma3_1b",
    "granite-3-8b": "granite_3_8b",
    "mamba2-370m": "mamba2_370m",
    "chameleon-34b": "chameleon_34b",
}


def get_config(arch: str) -> ArchConfig:
    mod_name = ARCH_ALIASES.get(arch)
    if mod_name is None:
        raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCH_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_ALIASES[arch]}")
    return mod.smoke_config()


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
