"""Gemma-3-1B [hf:google/gemma-3-1b-pt]: 26L, d_model 1152, 4 heads
(GQA kv=1, head_dim 256), d_ff 6912, vocab 262144; 5:1 local:global
attention (local window 1024... published 512; we keep 1024 per assignment),
tied embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab=262144,
    head_dim=256,
    local_global_ratio=5,
    local_window=1024,
    tie_embeddings=True,
    norm="rmsnorm",
    act="gelu",
    rope_theta=1_000_000.0,
    citation="hf:google/gemma-3-1b-pt",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256, vocab=512,
        head_dim=32, local_global_ratio=1, local_window=32,
        param_dtype="float32", compute_dtype="float32",
    )
