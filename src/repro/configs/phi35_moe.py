"""Phi-3.5-MoE-42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]: 32L,
d_model 4096, 32 heads (GQA kv=8), expert d_ff 6400, vocab 32064,
MoE 16 experts top-2."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    top_k=2,
    norm="rmsnorm",
    act="silu",
    param_dtype="bfloat16",  # 42B: bf16 param store (DESIGN.md §5)
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
        n_experts=4, top_k=2, param_dtype="float32", compute_dtype="float32",
    )
