"""OLMo-1B [arXiv:2402.00838]: 16L, d_model 2048, 16 heads (MHA), d_ff 8192,
vocab 50304, non-parametric LayerNorm, SwiGLU."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    norm="layernorm_np",
    act="silu",
    citation="arXiv:2402.00838",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
        param_dtype="float32", compute_dtype="float32",
    )
