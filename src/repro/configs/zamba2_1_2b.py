"""Zamba2-1.2B [arXiv:2411.15242]: 38 Mamba2 layers, d_model 2048, shared
attention block (32 heads MHA, d_ff 8192) invoked every 6 layers,
vocab 32000, ssm_state 64."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    attn_every=6,
    norm="rmsnorm",
    act="silu",
    citation="arXiv:2411.15242",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
        ssm_state=16,
        ssm_headdim=32,
        ssm_expand=2,
        attn_every=1,
        norm="rmsnorm",
        act="silu",
        param_dtype="float32",
        compute_dtype="float32",
        citation="arXiv:2411.15242",
    )
