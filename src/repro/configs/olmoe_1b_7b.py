"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d_model 2048, 16 heads, expert
d_ff 1024, vocab 50304, MoE 64 experts top-8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    norm="rmsnorm",
    act="silu",
    citation="arXiv:2409.02060",
)


def smoke_config() -> ArchConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        n_experts=4, top_k=2, param_dtype="float32", compute_dtype="float32",
    )
