"""pFedMe [T. Dinh et al. 2020] — personalization via Moreau envelopes.

Each client maintains a "global" iterate w_i; per round it approximately
solves θ_i = argmin f_i(θ) + λ/2 ||θ - w_i||² with K inner SGD steps, then
takes the outer step w_i <- w_i - η λ (w_i - θ_i). Decentralized variant
gossips w with the static Metropolis matrix. Personalized model = θ_i.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.common import gossip_avg
from repro.data.pipeline import client_uniform_batches


class PFedMeState(NamedTuple):
    w: any  # leaves (N, ...)


def init_state(key, model_init, n_clients: int) -> PFedMeState:
    return PFedMeState(w=jax.vmap(model_init)(jax.random.split(key, n_clients)))


def _inner_solve(loss_fn, w, data, key, k_inner, batch, inner_lr, lam):
    """K SGD steps on f_i(θ) + λ/2||θ - w||², θ init = w. Returns θ."""
    grad_fn = jax.grad(loss_fn)
    theta = w

    def one(theta, kk):
        bx, by = client_uniform_batches(kk, data["inputs"], data["targets"], batch)
        grads = jax.vmap(grad_fn)(theta, {"x": bx, "y": by})
        theta = jax.tree.map(
            lambda t, g, ww: t - inner_lr * (
                g + lam * (t.astype(jnp.float32) - ww.astype(jnp.float32))
            ).astype(t.dtype),
            theta, grads, w,
        )
        return theta, None

    keys = jax.random.split(key, k_inner)
    theta, _ = jax.lax.scan(one, theta, keys)
    return theta


def make_step(
    loss_fn: Callable,
    w_mix,
    *,
    tau: int,
    batch: int,
    lam: float = 15.0,
    k_inner: int = 5,
    inner_lr: float = 5e-2,
):
    w_mix = jnp.asarray(w_mix)

    def step(state: PFedMeState, data, key, lr):
        w = state.w

        def outer(w, kk):
            theta = _inner_solve(loss_fn, w, data, kk, k_inner, batch,
                                 inner_lr, lam)
            w = jax.tree.map(
                lambda ww, t: (
                    ww.astype(jnp.float32)
                    - lr * lam * (ww.astype(jnp.float32) - t.astype(jnp.float32))
                ).astype(ww.dtype),
                w, theta,
            )
            return w, None

        keys = jax.random.split(key, tau)
        w, _ = jax.lax.scan(outer, w, keys)
        w = gossip_avg(w, w_mix)
        return PFedMeState(w=w), {}

    return step


def personalized_params(
    state: PFedMeState, loss_fn, data, key, *, batch=32, lam=15.0,
    k_inner=10, inner_lr=5e-2,
):
    """θ_i from the final w_i (a fresh inner solve on local data)."""
    return _inner_solve(loss_fn, state.w, data, key, k_inner, batch,
                        inner_lr, lam)
