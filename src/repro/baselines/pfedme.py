"""pFedMe [T. Dinh et al. 2020] — personalization via Moreau envelopes.

Each client maintains a "global" iterate w_i; per round it approximately
solves θ_i = argmin f_i(θ) + λ/2 ||θ - w_i||² with K inner SGD steps, then
takes the outer step w_i <- w_i - η λ (w_i - θ_i). Decentralized variant
gossips w with the static Metropolis matrix. Personalized model = θ_i.

With ``pack_spec`` (core/packing.py) w lives on the packed (N, X) plane:
the inner proximal steps and the outer Moreau step are fused single-array
updates (the tree.map arithmetic below is polymorphic — a plane is a
one-leaf pytree) and the gossip is one (N,N)·(N,X) matmul.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.common import gossip_avg_comm
from repro.core.packing import PackSpec, maybe_unpack, pack, unpack
from repro.data.pipeline import client_uniform_batches


class PFedMeState(NamedTuple):
    w: any  # leaves (N, ...) — or the packed (N, X) plane
    ef: any = None  # (N, X) error-feedback residual (comm/codecs)


def init_state(key, model_init, n_clients: int,
               pack_spec: PackSpec | None = None) -> PFedMeState:
    w = jax.vmap(model_init)(jax.random.split(key, n_clients))
    if pack_spec is not None:
        w = pack(w, pack_spec)
    return PFedMeState(w=w)


def _inner_solve(loss_fn, w, data, key, k_inner, batch, inner_lr, lam,
                 pack_spec=None):
    """K SGD steps on f_i(θ) + λ/2||θ - w||², θ init = w. Returns θ.

    Packed w: the proximal pull is flat (N, X) arithmetic and the loss
    gradient is scatter-added into the plane (packing.flat_add_grads) —
    the loss re-enters pytree form only inside its forward."""
    grad_fn = jax.grad(loss_fn)
    theta = w

    def one_flat(theta, kk):
        bx, by = client_uniform_batches(kk, data["inputs"], data["targets"],
                                        batch)
        grads = jax.vmap(grad_fn)(unpack(theta, pack_spec),
                                  {"x": bx, "y": by})
        # θ ← θ − η·λ·(θ − w) − η·g, leaf-local slices so the whole inner
        # step is ONE in-place pass over the plane's X axis (a separate
        # full-width prox pass would double the write traffic)
        for o, sz, shape, g in zip(pack_spec.offsets, pack_spec.sizes,
                                   pack_spec.shapes, jax.tree.leaves(grads)):
            bnd = g.ndim - len(shape)
            gv = jnp.reshape(g, g.shape[:bnd] + (sz,)).astype(theta.dtype)
            sl = theta[..., o:o + sz]
            theta = theta.at[..., o:o + sz].add(
                -inner_lr * (lam * (sl - w[..., o:o + sz]) + gv)
            )
        return theta, None

    def one(theta, kk):
        bx, by = client_uniform_batches(kk, data["inputs"], data["targets"], batch)
        grads = jax.vmap(grad_fn)(theta, {"x": bx, "y": by})
        theta = jax.tree.map(
            lambda t, g, ww: t - inner_lr * (
                g + lam * (t.astype(jnp.float32) - ww.astype(jnp.float32))
            ).astype(t.dtype),
            theta, grads, w,
        )
        return theta, None

    keys = jax.random.split(key, k_inner)
    theta, _ = jax.lax.scan(one_flat if pack_spec is not None else one,
                            theta, keys)
    return theta


def make_step(
    loss_fn: Callable,
    w_mix,
    *,
    tau: int,
    batch: int,
    lam: float = 15.0,
    k_inner: int = 5,
    inner_lr: float = 5e-2,
    pack_spec: PackSpec | None = None,
    gossip_backend: str = "reference",
    channel=None,
):
    if channel is not None and pack_spec is None:
        raise ValueError("comm compression requires the packed plane")
    w_mix = jnp.asarray(w_mix)

    def step(state: PFedMeState, data, key, lr):
        w = state.w
        if channel is not None:
            key, k_comm = jax.random.split(key)
        else:
            k_comm = None

        def outer(w, kk):
            theta = _inner_solve(loss_fn, w, data, kk, k_inner, batch,
                                 inner_lr, lam, pack_spec=pack_spec)
            w = jax.tree.map(
                lambda ww, t: (
                    ww.astype(jnp.float32)
                    - lr * lam * (ww.astype(jnp.float32) - t.astype(jnp.float32))
                ).astype(ww.dtype),
                w, theta,
            )
            return w, None

        keys = jax.random.split(key, tau)
        w, _ = jax.lax.scan(outer, w, keys)
        w, ef = gossip_avg_comm(w, w_mix, channel=channel, key=k_comm,
                                ef=state.ef, backend=gossip_backend)
        return PFedMeState(w=w, ef=ef), {}

    return step


def personalized_params(
    state: PFedMeState, loss_fn, data, key, *, batch=32, lam=15.0,
    k_inner=10, inner_lr=5e-2, pack_spec: PackSpec | None = None,
):
    """θ_i from the final w_i (a fresh inner solve on local data). Packed
    states solve flat and re-enter pytree form only here — the API
    boundary."""
    theta = _inner_solve(loss_fn, state.w, data, key, k_inner, batch,
                         inner_lr, lam, pack_spec=pack_spec)
    return maybe_unpack(theta, pack_spec)
