"""FedAvg [McMahan et al. 2017] — centralized and decentralized (D-SGD
gossip) variants. The non-personalized reference point.

With ``pack_spec`` (core/packing.py) the state is the packed (N, X) plane:
local SGD is one fused update over the plane (the loss re-enters pytree
form only inside the forward) and the gossip average is a single
(N,N)·(N,X) matmul — or one Pallas streaming pass with
``gossip_backend="pallas"`` — instead of one einsum per leaf.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.baselines.common import gossip_avg_comm, local_sgd
from repro.comm.codecs import join_ef, split_ef
from repro.core.packing import PackSpec, maybe_unpack


def make_step(loss_fn: Callable, w, *, tau: int, batch: int,
              pack_spec: PackSpec | None = None,
              gossip_backend: str = "reference", channel=None):
    """``channel`` (comm/codecs.Channel) runs the exchange through a wire
    codec on the packed plane; with error feedback the state rides a
    ``WithEF`` wrapper so the residual crosses rounds."""
    if channel is not None and pack_spec is None:
        raise ValueError("comm compression requires the packed plane")
    w = jnp.asarray(w)

    def step(state, data, key, lr):
        params, ef = split_ef(state, channel)
        if channel is not None:
            key, k_comm = jax.random.split(key)
        else:
            k_comm = None
        params = local_sgd(loss_fn, params, data, key, tau, batch, lr,
                           pack_spec=pack_spec)
        mixed, ef = gossip_avg_comm(params, w, channel=channel, key=k_comm,
                                    ef=ef, backend=gossip_backend)
        return join_ef(mixed, ef, channel), {}

    return step


def personalized_params(params, pack_spec: PackSpec | None = None,
                        channel=None):
    """FedAvg has no personalization: every client evaluates its own copy
    (equal to the consensus model up to gossip error). Packed states
    re-enter pytree form here — the API boundary — and EF-wrapped states
    drop their residual rider."""
    params, _ = split_ef(params, channel)
    return maybe_unpack(params, pack_spec)
