"""FedAvg [McMahan et al. 2017] — centralized and decentralized (D-SGD
gossip) variants. The non-personalized reference point."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.baselines.common import gossip_avg, local_sgd


def make_step(loss_fn: Callable, w, *, tau: int, batch: int):
    w = jnp.asarray(w)

    def step(params, data, key, lr):
        params = local_sgd(loss_fn, params, data, key, tau, batch, lr)
        return gossip_avg(params, w), {}

    return step


def personalized_params(params):
    """FedAvg has no personalization: every client evaluates its own copy
    (equal to the consensus model up to gossip error)."""
    return params
