"""FedAvg [McMahan et al. 2017] — centralized and decentralized (D-SGD
gossip) variants. The non-personalized reference point.

With ``pack_spec`` (core/packing.py) the state is the packed (N, X) plane:
local SGD is one fused update over the plane (the loss re-enters pytree
form only inside the forward) and the gossip average is a single
(N,N)·(N,X) matmul — or one Pallas streaming pass with
``gossip_backend="pallas"`` — instead of one einsum per leaf.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.baselines.common import gossip_avg, local_sgd
from repro.core.packing import PackSpec, maybe_unpack


def make_step(loss_fn: Callable, w, *, tau: int, batch: int,
              pack_spec: PackSpec | None = None,
              gossip_backend: str = "reference"):
    w = jnp.asarray(w)

    def step(params, data, key, lr):
        params = local_sgd(loss_fn, params, data, key, tau, batch, lr,
                           pack_spec=pack_spec)
        return gossip_avg(params, w, backend=gossip_backend), {}

    return step


def personalized_params(params, pack_spec: PackSpec | None = None):
    """FedAvg has no personalization: every client evaluates its own copy
    (equal to the consensus model up to gossip error). Packed states
    re-enter pytree form here — the API boundary."""
    return maybe_unpack(params, pack_spec)
