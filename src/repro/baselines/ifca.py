"""IFCA [Ghosh et al. 2020] — hard clustering: each client picks the single
cluster whose model has the lowest loss on its full local data, trains that
model on ALL its data, and (decentralized variant) averages with neighbors
that picked the same cluster. No mixtures: the paper's hard-clustering
baseline."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.common import local_sgd
from repro.core.gossip import GossipSpec, mix_dense


class IFCAState(NamedTuple):
    centers: any       # leaves (S, N, ...)
    choice: jnp.ndarray  # (N,) hard assignment


def init_state(key, model_init, n_clients: int, s_clusters: int) -> IFCAState:
    keys = jax.random.split(key, s_clusters * n_clients).reshape(
        s_clusters, n_clients, -1
    )
    centers = jax.vmap(jax.vmap(model_init))(keys)
    return IFCAState(centers=centers, choice=jnp.zeros((n_clients,), jnp.int32))


def make_step(
    loss_fn: Callable,
    per_example_loss: Callable,
    gossip: GossipSpec,
    *,
    tau: int,
    batch: int,
):
    def step(state: IFCAState, data, key, lr):
        centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), state.centers)

        # hard cluster estimation on the full local dataset
        def pick(centers_i, data_i):
            losses = jax.vmap(
                lambda c: jnp.mean(per_example_loss(c, data_i))
            )(centers_i)
            return jnp.argmin(losses)

        choice = jax.vmap(pick)(
            centers_nc, {"x": data["inputs"], "y": data["targets"]}
        )
        n = choice.shape[0]
        c_sel = jax.tree.map(lambda l: l[choice, jnp.arange(n)], state.centers)
        c_sel = local_sgd(loss_fn, c_sel, data, key, tau, batch, lr)
        # same-choice neighborhood averaging (decentralized IFCA)
        c_mixed = mix_dense(gossip, c_sel, choice)
        centers = jax.tree.map(
            lambda l, v: l.at[choice, jnp.arange(n)].set(v.astype(l.dtype)),
            state.centers, c_mixed,
        )
        return IFCAState(centers=centers, choice=choice), {"choice": choice}

    return step


def personalized_params(state: IFCAState):
    n = state.choice.shape[0]
    return jax.tree.map(
        lambda l: l[state.choice, jnp.arange(n)], state.centers
    )
