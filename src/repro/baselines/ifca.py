"""IFCA [Ghosh et al. 2020] — hard clustering: each client picks the single
cluster whose model has the lowest loss on its full local data, trains that
model on ALL its data, and (decentralized variant) averages with neighbors
that picked the same cluster. No mixtures: the paper's hard-clustering
baseline.

With ``pack_spec`` (core/packing.py) the centers live on the packed
(S, N, X) plane: gather/scatter of the chosen models are single-array
indexing, local SGD is one fused update over (N, X), and the same-choice
mixing runs on the flat slab (``mix_dense`` is representation-
polymorphic). Losses re-enter pytree form only inside their forwards.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.common import local_sgd
from repro.core.gossip import GossipSpec, mix_dense
from repro.core.packing import PackSpec, maybe_unpack, pack, plane_losses


class IFCAState(NamedTuple):
    centers: any       # leaves (S, N, ...) — or the packed (S, N, X) plane
    choice: jnp.ndarray  # (N,) hard assignment
    ef: any = None     # (N, X) error-feedback residual (comm/codecs)


def init_state(key, model_init, n_clients: int, s_clusters: int,
               pack_spec: PackSpec | None = None) -> IFCAState:
    keys = jax.random.split(key, s_clusters * n_clients).reshape(
        s_clusters, n_clients, -1
    )
    centers = jax.vmap(jax.vmap(model_init))(keys)
    if pack_spec is not None:
        centers = pack(centers, pack_spec)
    return IFCAState(centers=centers, choice=jnp.zeros((n_clients,), jnp.int32))


def make_step(
    loss_fn: Callable,
    per_example_loss: Callable,
    gossip: GossipSpec,
    *,
    tau: int,
    batch: int,
    pack_spec: PackSpec | None = None,
    channel=None,
):
    if channel is not None and pack_spec is None:
        raise ValueError("comm compression requires the packed plane")
    # flat view of the per-example loss for the cluster-estimation forward;
    # local SGD takes the pytree loss + pack_spec (packing.flat_grad)
    _, per_example_loss = plane_losses(pack_spec, None, per_example_loss)

    def step(state: IFCAState, data, key, lr):
        if channel is not None:
            key, k_comm = jax.random.split(key)
        centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), state.centers)

        # hard cluster estimation on the full local dataset
        def pick(centers_i, data_i):
            losses = jax.vmap(
                lambda c: jnp.mean(per_example_loss(c, data_i))
            )(centers_i)
            return jnp.argmin(losses)

        choice = jax.vmap(pick)(
            centers_nc, {"x": data["inputs"], "y": data["targets"]}
        )
        n = choice.shape[0]
        c_sel = jax.tree.map(lambda l: l[choice, jnp.arange(n)], state.centers)
        c_sel = local_sgd(loss_fn, c_sel, data, key, tau, batch, lr,
                          pack_spec=pack_spec)
        # same-choice neighborhood averaging (decentralized IFCA) — the
        # transmitted chosen-model slab goes through the wire codec
        ef = state.ef
        if channel is not None:
            c_sel, ef = channel.roundtrip(c_sel, k_comm, ef)
        c_mixed = mix_dense(gossip, c_sel, choice)
        centers = jax.tree.map(
            lambda l, v: l.at[choice, jnp.arange(n)].set(v.astype(l.dtype)),
            state.centers, c_mixed,
        )
        return IFCAState(centers=centers, choice=choice, ef=ef), \
            {"choice": choice}

    return step


def personalized_params(state: IFCAState, pack_spec: PackSpec | None = None):
    n = state.choice.shape[0]
    chosen = jax.tree.map(
        lambda l: l[state.choice, jnp.arange(n)], state.centers
    )
    return maybe_unpack(chosen, pack_spec)
