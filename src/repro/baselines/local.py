"""Local-only training: the no-collaboration floor in the paper's tables.

With ``pack_spec`` the per-client models live on the packed (N, X) plane
and every SGD step is one fused update over the plane (core/packing.py).
"""
from __future__ import annotations

from typing import Callable

from repro.baselines.common import local_sgd
from repro.core.packing import PackSpec, maybe_unpack


def make_step(loss_fn: Callable, w=None, *, tau: int, batch: int,
              pack_spec: PackSpec | None = None):
    def step(params, data, key, lr):
        return local_sgd(loss_fn, params, data, key, tau, batch, lr,
                         pack_spec=pack_spec), {}

    return step


def personalized_params(params, pack_spec: PackSpec | None = None):
    return maybe_unpack(params, pack_spec)
