"""Local-only training: the no-collaboration floor in the paper's tables."""
from __future__ import annotations

from typing import Callable

from repro.baselines.common import local_sgd


def make_step(loss_fn: Callable, w=None, *, tau: int, batch: int):
    def step(params, data, key, lr):
        return local_sgd(loss_fn, params, data, key, tau, batch, lr), {}

    return step


def personalized_params(params):
    return params
