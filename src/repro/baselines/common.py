"""Shared machinery for the baseline FL algorithms (paper Section 6
baselines: FedAvg, FedEM, IFCA, FedSoft, pFedMe, Local — each in a
decentralized (static gossip matrix) and centralized (complete averaging)
variant)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import client_uniform_batches
from repro.graphs.mixing import metropolis_weights
from repro.graphs.topology import Graph
from repro.optim.sgd import Optimizer, sgd

PyTree = Any


def mixing_matrix(graph: Graph | None, n: int, centralized: bool) -> np.ndarray:
    """Centralized = exact global average (a server); decentralized =
    Metropolis gossip over the client graph."""
    if centralized:
        return np.full((n, n), 1.0 / n, dtype=np.float32)
    assert graph is not None
    return metropolis_weights(graph)


def gossip_avg(params: PyTree, w: jnp.ndarray) -> PyTree:
    """params leaves (N, ...) <- W @ params."""
    return jax.tree.map(
        lambda l: jnp.einsum(
            "ij,j...->i...", w.astype(jnp.float32), l.astype(jnp.float32)
        ).astype(l.dtype),
        params,
    )


def local_sgd(
    loss_fn: Callable,
    params: PyTree,  # (N, ...)
    data: dict,      # {"inputs": (N, M, d), "targets": (N, M)}
    key: jax.Array,
    tau: int,
    batch: int,
    lr,
    optimizer: Optimizer | None = None,
    extra_grad: Callable | None = None,  # (params) -> grad pytree to add
) -> PyTree:
    """τ uniform-batch SGD steps per client (vmapped)."""
    optimizer = optimizer or sgd()
    grad_fn = jax.grad(loss_fn)
    opt_state = jax.vmap(optimizer.init)(params)

    def one(carry, k):
        p, o = carry
        bx, by = client_uniform_batches(k, data["inputs"], data["targets"], batch)
        grads = jax.vmap(grad_fn)(p, {"x": bx, "y": by})
        if extra_grad is not None:
            reg = extra_grad(p)
            grads = jax.tree.map(jnp.add, grads, reg)
        p, o = jax.vmap(lambda g, oo, pp: optimizer.update(g, oo, pp, lr))(
            grads, o, p
        )
        return (p, o), None

    keys = jax.random.split(key, tau)
    (params, _), _ = jax.lax.scan(one, (params, opt_state), keys)
    return params


def per_client_eval(metric_fn: Callable, params: PyTree, data: dict) -> jnp.ndarray:
    """metric_fn(params_i, batch_i) vmapped over clients -> (N,)."""
    return jax.vmap(metric_fn)(
        params, {"x": data["inputs"], "y": data["targets"]}
    )
