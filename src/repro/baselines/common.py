"""Shared machinery for the baseline FL algorithms (paper Section 6
baselines: FedAvg, FedEM, IFCA, FedSoft, pFedMe, Local — each in a
decentralized (static gossip matrix) and centralized (complete averaging)
variant).

Every helper here is polymorphic over the two parameter representations:

- pytree: model leaves with a leading client/cluster batch prefix — the
  historical layout, one tree walk per stage;
- packed plane (core/packing.py): ONE flat (N, X) / (S, N, X) fp32 array.
  A bare array is a one-leaf pytree, so ``gossip_avg`` / ``local_sgd``
  collapse to single-array arithmetic on it; the loss/grad boundary is
  bridged by ``packing.plane_losses`` (pytree re-entry only inside the
  forward pass). The baseline modules pass ``pack_spec`` through to here.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import flat_add_grads, flat_grad, unpack
from repro.data.pipeline import client_uniform_batches
from repro.graphs.mixing import metropolis_weights
from repro.graphs.topology import Graph
from repro.optim.sgd import Optimizer, sgd

PyTree = Any


def mixing_matrix(graph: Graph | None, n: int, centralized: bool) -> np.ndarray:
    """Centralized = exact global average (a server); decentralized =
    Metropolis gossip over the client graph."""
    if centralized:
        return np.full((n, n), 1.0 / n, dtype=np.float32)
    assert graph is not None
    return metropolis_weights(graph)


_GOSSIP_BACKENDS = ("reference", "pallas")


def _require_gossip_backend(backend: str) -> None:
    if backend not in _GOSSIP_BACKENDS:
        raise ValueError(
            f"unknown baseline gossip backend {backend!r}; "
            f"expected one of {_GOSSIP_BACKENDS}"
        )


def gossip_avg(params: PyTree, w: jnp.ndarray, *,
               backend: str = "reference") -> PyTree:
    """params leaves (N, ...) <- W @ params.

    On the packed (N, X) plane the reference path is ONE (N,N)·(N,X)
    matmul; ``backend="pallas"`` streams each leaf's flattened (N, -1)
    view through the kernels/gossip_mix Pallas kernel instead — exactly
    one ``pallas_call`` for a plane input."""
    _require_gossip_backend(backend)
    if backend == "pallas":
        from repro.kernels.gossip_mix import gossip_mix_tree

        return gossip_mix_tree(
            w, params, interpret=jax.default_backend() != "tpu"
        )
    return jax.tree.map(
        lambda l: jnp.einsum(
            "ij,j...->i...", w.astype(jnp.float32), l.astype(jnp.float32)
        ).astype(l.dtype),
        params,
    )


def gossip_avg_stack(plane: jnp.ndarray, w: jnp.ndarray, *,
                     backend: str = "reference") -> jnp.ndarray:
    """Packed (S, N, X) center stacks <- W @ C_s for EVERY cluster s in one
    shot (the FedEM exchange): one einsum on the reference path, one
    ``pallas_call`` with an (S, x_blocks) grid on the Pallas path — versus
    the pytree layout's per-leaf-per-cluster walks."""
    _require_gossip_backend(backend)
    if backend == "pallas":
        from repro.kernels.gossip_mix import gossip_mix_stack

        return gossip_mix_stack(
            w, plane, interpret=jax.default_backend() != "tpu"
        ).astype(plane.dtype)
    return jnp.einsum(
        "ij,sjx->six", w.astype(jnp.float32), plane.astype(jnp.float32)
    ).astype(plane.dtype)


def gossip_avg_comm(plane: jnp.ndarray, w: jnp.ndarray, *,
                    channel=None, key=None, ef=None,
                    backend: str = "reference"):
    """Compressed W-average on the packed plane: W · decode(encode(x + e)).

    ``plane`` is the (N, X) per-client plane or FedEM's (S, N, X) stack
    (all S models move, the codec applies to every message). With
    ``channel=None`` this is EXACTLY ``gossip_avg`` / ``gossip_avg_stack``
    — the uncompressed code path, bit for bit. On the Pallas backend the
    quantization codecs feed the fused dequantize+mix kernel directly
    (the mix's HBM read side is the int8 payload); top-k decodes outside
    and streams the dense mix. Returns (mixed, ef')."""
    if channel is None:
        # pytree states (no pack_spec) also pass through here untouched
        mixed = (gossip_avg_stack(plane, w, backend=backend)
                 if getattr(plane, "ndim", 0) == 3
                 else gossip_avg(plane, w, backend=backend))
        return mixed, ef
    if backend == "pallas" and channel.fused and plane.ndim == 2:
        from repro.kernels.gossip_mix import gossip_mix_encoded

        enc, _hat, ef = channel.encode_stream(plane, key, ef)
        return gossip_mix_encoded(
            w, enc, qblock=channel.cfg.block, x_out=plane.shape[-1],
            out_dtype=plane.dtype,
            interpret=jax.default_backend() != "tpu",
        ), ef
    x_hat, ef = channel.roundtrip(plane, key, ef)
    mixed = (gossip_avg_stack(x_hat, w, backend=backend)
             if plane.ndim == 3
             else gossip_avg(x_hat, w, backend=backend))
    return mixed.astype(plane.dtype), ef


def local_sgd(
    loss_fn: Callable,  # PYTREE-parameter loss, packed or not
    params: PyTree,  # (N, ...) leaves — or the packed (N, X) plane
    data: dict,      # {"inputs": (N, M, d), "targets": (N, M)}
    key: jax.Array,
    tau: int,
    batch: int,
    lr,
    optimizer: Optimizer | None = None,
    extra_grad: Callable | None = None,  # (params) -> grad pytree to add
    pack_spec=None,
) -> PyTree:
    """τ uniform-batch SGD steps per client (vmapped).

    With ``pack_spec`` (core/packing.py) ``params`` is the packed (N, X)
    plane: the loss re-enters pytree form only inside its forward, leaf
    gradients are scatter-added straight into the (donated) plane
    (``packing.flat_add_grads`` — no flat-grad concat, no per-leaf
    parameter walk), and any ``extra_grad`` regularizer is flat (N, X)
    arithmetic. A stateful ``optimizer`` falls back to flat gradients
    through ``packing.flat_grad``. ``loss_fn`` is the pytree-parameter
    loss in both representations."""
    if pack_spec is not None and optimizer is None:
        # paper-faithful stateless SGD on the plane
        grad_fn = jax.grad(loss_fn)

        def one_flat(vec, k):
            bx, by = client_uniform_batches(k, data["inputs"],
                                            data["targets"], batch)
            grads = jax.vmap(grad_fn)(unpack(vec, pack_spec),
                                      {"x": bx, "y": by})
            if extra_grad is not None:
                vec = vec - lr * extra_grad(vec)
            return flat_add_grads(vec, grads, -lr, pack_spec), None

        params, _ = jax.lax.scan(one_flat, params,
                                 jax.random.split(key, tau))
        return params

    optimizer = optimizer or sgd()
    grad_fn = (flat_grad(loss_fn, pack_spec) if pack_spec is not None
               else jax.grad(loss_fn))
    opt_state = jax.vmap(optimizer.init)(params)

    def one(carry, k):
        p, o = carry
        bx, by = client_uniform_batches(k, data["inputs"], data["targets"], batch)
        grads = jax.vmap(grad_fn)(p, {"x": bx, "y": by})
        if extra_grad is not None:
            reg = extra_grad(p)
            grads = jax.tree.map(jnp.add, grads, reg)
        p, o = jax.vmap(lambda g, oo, pp: optimizer.update(g, oo, pp, lr))(
            grads, o, p
        )
        return (p, o), None

    keys = jax.random.split(key, tau)
    (params, _), _ = jax.lax.scan(one, (params, opt_state), keys)
    return params


def per_client_eval(metric_fn: Callable, params: PyTree, data: dict) -> jnp.ndarray:
    """metric_fn(params_i, batch_i) vmapped over clients -> (N,)."""
    return jax.vmap(metric_fn)(
        params, {"x": data["inputs"], "y": data["targets"]}
    )
