"""FedSoft [Ruan & Joe-Wong 2022] — soft clustering with proximal local
updates. Each client trains ONE local model y_i on ALL of its data with a
proximal pull toward every cluster center (weighted by importance u_is);
centers are then importance-weighted aggregates of client models — over the
whole population (centralized) or the graph neighborhood (decentralized).

Appendix C of the FedSPD paper argues exactly this update is what biases
FedSoft's gradients toward a mixture of optima and breaks consensus in
low-connectivity DFL — reproduced in our connectivity benchmark.

With ``pack_spec`` (core/packing.py) both the center stack (S, N, X) and
the client models y (N, X) are packed planes: the proximal pull, the
local SGD, and the importance-weighted aggregation are all single-array
arithmetic (the per-leaf closures below are representation-polymorphic —
a plane is a one-leaf pytree); losses re-enter pytree form only inside
their forwards.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.common import local_sgd
from repro.core.clustering import mixture_coefficients
from repro.core.packing import PackSpec, maybe_unpack, pack, plane_losses


class FedSoftState(NamedTuple):
    centers: any       # leaves (S, N, ...) — each client's center estimates
    y: any             # leaves (N, ...)    — client local models
    u: jnp.ndarray     # (N, S)
    ef: any = None     # (N, X) error-feedback residual on the transmitted
    #                    client models y (comm/codecs); None unless EF is on


def init_state(key, model_init, n_clients: int, s_clusters: int,
               pack_spec: PackSpec | None = None) -> FedSoftState:
    k1, k2 = jax.random.split(key)
    keys = jax.random.split(k1, s_clusters * n_clients).reshape(
        s_clusters, n_clients, -1
    )
    centers = jax.vmap(jax.vmap(model_init))(keys)
    y = jax.vmap(model_init)(jax.random.split(k2, n_clients))
    if pack_spec is not None:
        centers, y = pack(centers, pack_spec), pack(y, pack_spec)
    u = jnp.full((n_clients, s_clusters), 1.0 / s_clusters, jnp.float32)
    return FedSoftState(centers=centers, y=y, u=u)


def make_step(
    loss_fn: Callable,
    per_example_loss: Callable,
    w,  # (N, N) mixing/aggregation weights (neighborhood or global)
    *,
    tau: int,
    batch: int,
    s_clusters: int,
    prox_lambda: float = 0.1,
    pack_spec: PackSpec | None = None,
    channel=None,
):
    if channel is not None and pack_spec is None:
        raise ValueError("comm compression requires the packed plane")
    w = jnp.asarray(w)
    # flat view of the per-example loss for the importance forward; local
    # SGD takes the pytree loss + pack_spec (packing.flat_grad)
    _, per_example_loss = plane_losses(pack_spec, None, per_example_loss)

    def step(state: FedSoftState, data, key, lr):
        if channel is not None:
            key, k_comm = jax.random.split(key)
        centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), state.centers)

        # importance estimation: per-point min-loss counts (FedSoft Eq. 4)
        def importance(centers_i, data_i):
            losses = jax.vmap(lambda c: per_example_loss(c, data_i))(centers_i)
            z = jnp.argmin(losses, axis=0)
            return mixture_coefficients(z, s_clusters)

        u = jax.vmap(importance)(
            centers_nc, {"x": data["inputs"], "y": data["targets"]}
        )

        # proximal local training of y_i on ALL data
        def prox_grad(y):
            # λ Σ_s u_is (y - c_is) per client, vmapped leaf arithmetic
            def per_leaf(y_l, c_l):
                # y_l (N, ...), c_l (S, N, ...)
                uu = u.T.reshape((s_clusters, -1) + (1,) * (y_l.ndim - 1))
                pull = jnp.sum(uu * (y_l[None] - c_l.astype(jnp.float32)), axis=0)
                return prox_lambda * pull

            return jax.tree.map(per_leaf, y, state.centers)

        y = local_sgd(
            loss_fn, state.y, data, key, tau, batch, lr,
            extra_grad=prox_grad, pack_spec=pack_spec,
        )

        # what crosses the wire is the client model y_i; the receivers'
        # center aggregation then runs on the decoded values while each
        # client keeps its own y exact
        ef = state.ef
        y_tx = y
        if channel is not None:
            y_tx, ef = channel.roundtrip(y, k_comm, ef)

        # importance-weighted center aggregation over the neighborhood
        def agg_leaf(y_l):
            # c_s[i] = Σ_j W_ij u_js y_j / Σ_j W_ij u_js
            y32 = y_l.astype(jnp.float32)
            out = []
            for s_idx in range(s_clusters):
                wu = w * u[None, :, s_idx]  # (N, N)
                denom = jnp.sum(wu, axis=1, keepdims=True)
                wu = wu / jnp.maximum(denom, 1e-9)
                out.append(jnp.einsum("ij,j...->i...", wu, y32))
            return jnp.stack(out, axis=0).astype(y_l.dtype)

        centers = jax.tree.map(agg_leaf, y_tx)
        return FedSoftState(centers=centers, y=y, u=u, ef=ef), {"u": u}

    return step


def personalized_params(state: FedSoftState,
                        pack_spec: PackSpec | None = None):
    return maybe_unpack(state.y, pack_spec)
