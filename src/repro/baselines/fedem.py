"""FedEM [Marfoq et al. 2021] — federated EM over a mixture of S
distributions. Every client trains ALL S cluster models every round
(responsibility-weighted) and exchanges ALL S models: per-round computation
and communication are S× FedSPD's (the comparison the paper draws in §6.3).

Decentralized variant: each of the S stacks is gossip-averaged with the
static Metropolis matrix. Personalized prediction = u-weighted mixture.

With ``pack_spec`` (core/packing.py) the whole (S, N, X) center stack is
ONE packed plane: the responsibility-weighted M-step updates are fused
single-array SGD (the per-example loss re-enters pytree form only inside
its forward), and the all-S exchange — FedEM's S× communication cost — is
one einsum over the stack (or one Pallas call with
``gossip_backend="pallas"``) instead of S × n_leaves walks.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.common import gossip_avg, gossip_avg_comm
from repro.core.packing import (
    PackSpec,
    flat_add_grads,
    pack,
    plane_losses,
    unpack,
)


class FedEMState(NamedTuple):
    centers: any      # leaves (S, N, ...) — or the packed (S, N, X) plane
    u: jnp.ndarray    # (N, S)
    ef: any = None    # (S, N, X) error-feedback residual (comm/codecs) —
    #                   FedEM ships ALL S models, so the residual covers
    #                   the whole stack; None unless an EF codec is on


def init_state(key, model_init, n_clients: int, s_clusters: int,
               pack_spec: PackSpec | None = None) -> FedEMState:
    keys = jax.random.split(key, s_clusters * n_clients).reshape(
        s_clusters, n_clients, -1
    )
    centers = jax.vmap(jax.vmap(model_init))(keys)
    if pack_spec is not None:
        centers = pack(centers, pack_spec)
    u = jnp.full((n_clients, s_clusters), 1.0 / s_clusters, jnp.float32)
    return FedEMState(centers=centers, u=u)


def make_step(
    loss_fn: Callable,          # unused (kept for uniform factory signature)
    per_example_loss: Callable, # (params, {"x","y"}) -> (B,)
    w,
    *,
    tau: int,
    batch: int,
    s_clusters: int,
    pack_spec: PackSpec | None = None,
    gossip_backend: str = "reference",
    channel=None,
):
    if channel is not None and pack_spec is None:
        raise ValueError("comm compression requires the packed plane")
    w = jnp.asarray(w)
    # flat view of the per-example loss for the E-step forwards; the
    # M-step gradient goes through packing.flat_grad on the pytree loss
    pel_tree = per_example_loss
    _, per_example_loss = plane_losses(pack_spec, None, per_example_loss)

    def e_step(centers, u, data):
        """Responsibilities r (N, M, S) ∝ u_is · exp(-ℓ(c_s; d))."""
        centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), centers)

        def one(centers_i, data_i, u_i):
            losses = jax.vmap(
                lambda c: per_example_loss(c, data_i)
            )(centers_i)  # (S, M)
            logr = jnp.log(jnp.maximum(u_i, 1e-12))[:, None] - losses
            return jax.nn.softmax(logr, axis=0).T  # (M, S)

        return jax.vmap(one)(
            centers_nc, {"x": data["inputs"], "y": data["targets"]}, u
        )

    def step(state: FedEMState, data, key, lr):
        if channel is not None:
            key, k_comm = jax.random.split(key)
        else:
            k_comm = None
        r = e_step(state.centers, state.u, data)  # (N, M, S)
        u = jnp.mean(r, axis=1)  # (N, S)

        # M-step: τ responsibility-weighted SGD steps for EVERY cluster model
        def train_cluster(c_s, r_s, k):
            # c_s leaves (N, ...) — or the (N, X) plane slab — r_s (N, M)
            def weighted_loss(params, batch_i, rw):
                pel = pel_tree(params, batch_i)
                return jnp.sum(pel * rw) / jnp.maximum(jnp.sum(rw), 1e-6)

            wgrad = jax.grad(weighted_loss)

            def one(carry, kk):
                p = carry
                k1, k2 = jax.random.split(kk)
                n, m = r_s.shape
                idx = jax.random.randint(k1, (n, batch), 0, m)
                bx = jnp.take_along_axis(
                    data["inputs"], idx[..., None], axis=1
                )
                by = jnp.take_along_axis(data["targets"], idx, axis=1)
                rw = jnp.take_along_axis(r_s, idx, axis=1)
                if pack_spec is not None:
                    # leaf grads scatter-added into the (N, X) plane slab
                    grads = jax.vmap(wgrad)(unpack(p, pack_spec),
                                            {"x": bx, "y": by}, rw)
                    p = flat_add_grads(p, grads, -lr, pack_spec)
                else:
                    grads = jax.vmap(wgrad)(p, {"x": bx, "y": by}, rw)
                    p = jax.tree.map(lambda pp, g: pp - lr * g, p, grads)
                return p, None

            keys = jax.random.split(k, tau)
            c_s, _ = jax.lax.scan(one, c_s, keys)
            return c_s

        keys = jax.random.split(key, s_clusters)
        centers = jax.vmap(train_cluster, in_axes=(0, 2, 0))(
            state.centers, r, keys
        )
        # exchange ALL S models (the S× communication cost); the packed
        # plane mixes the whole (S, N, X) stack in one shot — with a
        # channel, every one of the S messages goes through the codec
        ef = state.ef
        if pack_spec is not None:
            centers, ef = gossip_avg_comm(
                centers, w, channel=channel, key=k_comm, ef=ef,
                backend=gossip_backend,
            )
        else:
            centers = jax.vmap(lambda c_s: gossip_avg(c_s, w))(centers)
        return FedEMState(centers=centers, u=u, ef=ef), {"u": u}

    return step


def mixture_predict(apply_fn: Callable, state: FedEMState, x_i, u_i, centers_i):
    """Per-client mixture prediction: Σ_s u_s softmax(logits_s)."""
    logits = jax.vmap(lambda c: apply_fn(c, x_i))(centers_i)  # (S, B, K)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("s,sbk->bk", u_i, probs)


def personalized_accuracy(apply_fn: Callable, state: FedEMState, data,
                          pack_spec: PackSpec | None = None) -> jnp.ndarray:
    if pack_spec is not None:
        from repro.core.packing import flat_apply

        apply_fn = flat_apply(apply_fn, pack_spec)
    centers_nc = jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), state.centers)

    def one(centers_i, u_i, x_i, y_i):
        probs = mixture_predict(apply_fn, state, x_i, u_i, centers_i)
        return jnp.mean((jnp.argmax(probs, -1) == y_i).astype(jnp.float32))

    return jax.vmap(one)(centers_nc, state.u, data["inputs"], data["targets"])
