from repro.data.pipeline import (  # noqa: F401
    client_batches,
    client_uniform_batches,
    gather_batch,
    sample_cluster_batch_indices,
    sample_uniform_batch_indices,
)
from repro.data.synthetic import (  # noqa: F401
    ClientDataset,
    make_mixture_classification,
    make_mixture_tokens,
    make_unbalanced_quantity,
)
