"""Synthetic mixture-of-clusters datasets.

The paper builds client data as a mixture of S distributions obtained from a
base dataset via *rotation* (90° image rotation) and/or *label split*
(even/odd labels), with per-client mixture fractions drawn uniformly from
[10%, 90%] (Appendix B.1). No datasets ship in this offline container, so we
reproduce the same *construction* on synthetic data whose analogue is exact:

- ``rotated_prototypes``: K class prototypes in R^d with Gaussian noise;
  cluster 2 applies a fixed orthogonal "rotation" R to inputs. A linear/MLP
  model fits either cluster well but not both — the same tension the paper's
  rotated MNIST creates.
- ``label_split``: cluster 2 permutes the label map (even/odd-style), so a
  single model cannot be Bayes-optimal for both clusters.
- S=4 combines both, mirroring the paper's CIFAR construction
  (rotated-even / unrotated-even / rotated-odd / unrotated-odd).

Token-stream mixtures (for the LLM substrate) give each cluster its own
Markov chain over the vocab; per-client documents are drawn from the
client's mixture, again with U[0.1, 0.9] fractions.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClientDataset:
    """Per-client supervised data with ground-truth cluster provenance.

    x: (N, M, ...) inputs    y: (N, M) int labels
    z_true: (N, M) int true cluster of each point (hidden from algorithms;
            used only for evaluation of clustering quality)
    mix_true: (N, S) true mixture fractions
    x_test/y_test/z_test: per-client held-out split (N, Mt, ...).
    """

    x: np.ndarray
    y: np.ndarray
    z_true: np.ndarray
    mix_true: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    z_test: np.ndarray
    n_classes: int
    n_clusters: int

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]

    @property
    def points_per_client(self) -> int:
        return self.x.shape[1]


def _mixture_counts(
    rng: np.random.Generator, n_clients: int, s: int, m: int,
    lo: float = 0.1, hi: float = 0.9,
) -> np.ndarray:
    """Counts (N, S) per client per cluster, paper-style U[lo,hi] fractions."""
    if s == 1:
        return np.full((n_clients, 1), m, dtype=np.int64)
    # draw the fraction for a random "primary" split, distribute remainder
    counts = np.zeros((n_clients, s), dtype=np.int64)
    for i in range(n_clients):
        fracs = rng.uniform(lo, hi, size=s)
        fracs = fracs / fracs.sum()
        c = np.floor(fracs * m).astype(np.int64)
        c[rng.integers(s)] += m - c.sum()
        counts[i] = c
    return counts


def make_mixture_classification(
    n_clients: int = 20,
    n_clusters: int = 2,
    n_per_client: int = 256,
    n_test_per_client: int = 128,
    n_classes: int = 10,
    dim: int = 64,
    noise: float = 0.45,
    mode: str = "rotate",  # rotate | label_split | both
    seed: int = 0,
) -> ClientDataset:
    """Gaussian-prototype classification with rotation / label-split clusters."""
    assert mode in ("rotate", "label_split", "both")
    if mode == "both":
        assert n_clusters == 4, "mode='both' composes 2x2 clusters"
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((n_classes, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)

    # orthogonal "rotation" transforms, one per rotation-cluster
    q, _ = np.linalg.qr(rng.standard_normal((dim, dim)))
    rotations = [np.eye(dim, dtype=np.float32), q.astype(np.float32)]
    # label permutation for label-split clusters (even/odd-style swap)
    perm = np.arange(n_classes)
    perm = np.roll(perm, n_classes // 2)

    def cluster_xform(s: int):
        if mode == "rotate":
            return rotations[s % 2], np.arange(n_classes)
        if mode == "label_split":
            return rotations[0], (perm if s % 2 else np.arange(n_classes))
        rot = rotations[s % 2]
        lab = perm if (s // 2) % 2 else np.arange(n_classes)
        return rot, lab

    m_tr, m_te = n_per_client, n_test_per_client
    counts_tr = _mixture_counts(rng, n_clients, n_clusters, m_tr)
    mix_true = counts_tr / m_tr

    def sample(counts_row):
        xs, ys, zs = [], [], []
        for s, c in enumerate(counts_row):
            if c == 0:
                continue
            rot, lab = cluster_xform(s)
            labels = rng.integers(n_classes, size=c)
            pts = protos[labels] + noise * rng.standard_normal((c, dim)).astype(
                np.float32
            )
            xs.append(pts @ rot.T)
            ys.append(lab[labels])
            zs.append(np.full(c, s, dtype=np.int64))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        z = np.concatenate(zs)
        p = rng.permutation(len(x))
        return x[p], y[p], z[p]

    X, Y, Z = [], [], []
    Xt, Yt, Zt = [], [], []
    for i in range(n_clients):
        x, y, z = sample(counts_tr[i])
        X.append(x); Y.append(y); Z.append(z)
        # test split uses the same mixture proportions
        counts_te = np.maximum(
            1, np.round(mix_true[i] * m_te)
        ).astype(np.int64)
        counts_te[np.argmax(counts_te)] += m_te - counts_te.sum()
        counts_te = np.maximum(counts_te, 0)
        xt, yt, zt = sample(counts_te)
        Xt.append(xt[:m_te]); Yt.append(yt[:m_te]); Zt.append(zt[:m_te])

    return ClientDataset(
        x=np.stack(X).astype(np.float32),
        y=np.stack(Y).astype(np.int64),
        z_true=np.stack(Z),
        mix_true=mix_true.astype(np.float32),
        x_test=np.stack(Xt).astype(np.float32),
        y_test=np.stack(Yt).astype(np.int64),
        z_test=np.stack(Zt),
        n_classes=n_classes,
        n_clusters=n_clusters,
    )


def make_unbalanced_quantity(
    base: ClientDataset, ratio: float, seed: int = 0
) -> ClientDataset:
    """Appendix B.2.5: low/average/high data holders with max/min ratio r.

    We subsample each client's training set so that a third of clients keep
    m/r points, a third keep m, a third keep m (padded semantics kept simple:
    low holders' remaining slots repeat their own data, preserving shapes).
    """
    rng = np.random.default_rng(seed)
    n, m = base.x.shape[0], base.x.shape[1]
    x, y, z = base.x.copy(), base.y.copy(), base.z_true.copy()
    groups = np.array_split(rng.permutation(n), 3)
    low = groups[0]
    keep_low = max(8, int(round(m / max(ratio, 1.0))))
    for i in low:
        idx = rng.choice(m, size=keep_low, replace=False)
        rep = idx[rng.integers(keep_low, size=m)]
        x[i], y[i], z[i] = x[i][rep], y[i][rep], z[i][rep]
    return dataclasses.replace(base, x=x, y=y, z_true=z)


def make_mixture_tokens(
    n_clients: int = 16,
    n_clusters: int = 2,
    docs_per_client: int = 64,
    seq_len: int = 256,
    vocab: int = 512,
    seed: int = 0,
    concentration: float = 0.25,
) -> dict:
    """Cluster-specific Markov chains over a shared vocab.

    Returns dict with tokens (N, D, L) int32, z_true (N, D), mix_true (N, S).
    Each cluster's transition matrix is a sparse-ish Dirichlet draw, so
    next-token statistics genuinely differ across clusters — the LLM analogue
    of the paper's rotated-image clusters.
    """
    rng = np.random.default_rng(seed)
    trans = []
    for s in range(n_clusters):
        t = rng.dirichlet(np.full(vocab, concentration), size=vocab)
        trans.append(t.astype(np.float64))
    counts = _mixture_counts(rng, n_clients, n_clusters, docs_per_client)

    tokens = np.zeros((n_clients, docs_per_client, seq_len), dtype=np.int32)
    z_true = np.zeros((n_clients, docs_per_client), dtype=np.int64)
    for i in range(n_clients):
        d = 0
        for s, c in enumerate(counts[i]):
            for _ in range(c):
                seq = np.zeros(seq_len, dtype=np.int32)
                seq[0] = rng.integers(vocab)
                t = trans[s]
                for k in range(1, seq_len):
                    seq[k] = rng.choice(vocab, p=t[seq[k - 1]])
                tokens[i, d] = seq
                z_true[i, d] = s
                d += 1
        p = rng.permutation(docs_per_client)
        tokens[i] = tokens[i][p]
        z_true[i] = z_true[i][p]
    return {
        "tokens": tokens,
        "z_true": z_true,
        "mix_true": (counts / docs_per_client).astype(np.float32),
        "vocab": vocab,
        "n_clusters": n_clusters,
    }
