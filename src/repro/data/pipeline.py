"""Jit-safe per-client batch sampling.

FedSPD's local-training step samples uniformly from D_{i,s} — the points of
client i *currently assigned* to the selected cluster s (assignments z come
from the previous round's clustering step and live on device). We implement
masked categorical sampling with a uniform fallback when a client has no
points in the selected cluster (can happen early in training before the
clustering stabilizes; the paper's probabilistic selection makes this rare
since u_{i,s}=0 clusters are never selected, but we guard it numerically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_cluster_batch_indices(
    key: jax.Array,
    z: jax.Array,  # (M,) current cluster assignment per data point
    s: jax.Array,  # () selected cluster for this client
    batch: int,
) -> jax.Array:
    """Indices (batch,) drawn uniformly-with-replacement from {k : z[k]==s};
    falls back to uniform over all points if the set is empty."""
    match = (z == s)
    any_match = jnp.any(match)
    logits = jnp.where(match | ~any_match, 0.0, -jnp.inf)
    return jax.random.categorical(key, logits, shape=(batch,))


def sample_uniform_batch_indices(key: jax.Array, m: int, batch: int) -> jax.Array:
    return jax.random.randint(key, (batch,), 0, m)


def gather_batch(data: jax.Array, idx: jax.Array) -> jax.Array:
    """data (M, ...) , idx (B,) -> (B, ...)."""
    return jnp.take(data, idx, axis=0)


def client_batches(
    key: jax.Array,
    x: jax.Array,  # (N, M, ...)
    y: jax.Array,  # (N, M)
    z: jax.Array,  # (N, M)
    s: jax.Array,  # (N,) selected cluster per client
    batch: int,
) -> tuple[jax.Array, jax.Array]:
    """vmapped cluster-conditional batch for every client: (N, B, ...)."""
    keys = jax.random.split(key, x.shape[0])

    def one(k, xi, yi, zi, si):
        idx = sample_cluster_batch_indices(k, zi, si, batch)
        return gather_batch(xi, idx), gather_batch(yi, idx)

    return jax.vmap(one)(keys, x, y, z, s)


def client_uniform_batches(
    key: jax.Array, x: jax.Array, y: jax.Array, batch: int
) -> tuple[jax.Array, jax.Array]:
    """Plain per-client uniform batches (baselines + final personalization)."""
    n, m = x.shape[0], x.shape[1]
    keys = jax.random.split(key, n)

    def one(k, xi, yi):
        idx = sample_uniform_batch_indices(k, m, batch)
        return gather_batch(xi, idx), gather_batch(yi, idx)

    return jax.vmap(one)(keys, x, y)
