"""ShapeDtypeStruct input specs for every (arch × input-shape × step).

``input_specs`` / ``build_dryrun`` produce weak-type-correct, shardable
stand-ins for every model input — no device allocation — so the launch layer
can ``jax.jit(step).lower(*specs).compile()`` the full production program on
a placeholder mesh (MULTI-POD DRY-RUN in the brief).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ArchConfig, get_config
from repro.core.fedspd import FedSPDConfig, FedSPDState
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes, dp_size
from repro.launch.steps import (
    arch_for_shape,
    make_decode_step,
    make_fedspd_train_step,
    make_gossip,
    make_plain_train_step,
    make_prefill_step,
    supports_shape,
)
from repro.models.registry import ModelBundle, build_model
from repro.optim.sgd import make_optimizer

PyTree = Any


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def _attach(tree_sds: PyTree, pspecs: PyTree, mesh) -> PyTree:
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)
        ),
        tree_sds,
        pspecs,
    )


def param_specs(bundle: ModelBundle, mesh) -> PyTree:
    """Sharded SDS for one model's parameters (tensor-parallel rules)."""
    sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    return _attach(sds, shd.params_pspecs(sds, mesh), mesh)


def fedspd_state_specs(bundle: ModelBundle, fcfg: FedSPDConfig, mesh,
                       replicate_model_dims: bool = False) -> FedSPDState:
    """Sharded SDS for the FL state: centers (S, N, ·) client-sharded."""
    dp = dp_axes(mesh)
    p_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))

    def center(path, leaf):
        if replicate_model_dims:
            inner = P(*([None] * len(leaf.shape)))
        else:
            inner = shd.param_spec(path, leaf.shape, mesh)
        return _sds(
            (fcfg.n_clusters, fcfg.n_clients) + leaf.shape, leaf.dtype, mesh,
            P(None, dp, *inner),
        )

    centers = jax.tree_util.tree_map_with_path(center, p_sds)
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return FedSPDState(
        centers=centers,
        u=_sds((fcfg.n_clients, fcfg.n_clusters), jnp.float32, mesh, P(dp, None)),
        z=_sds((fcfg.n_clients, 1), jnp.int32, mesh, P(dp, None)),
        round=_sds((), jnp.int32, mesh, P()),
        key=_sds(key_sds.shape, key_sds.dtype, mesh, P()),
        comm_bytes=_sds((), jnp.float32, mesh, P()),
    )


def _token_batch(cfg: ArchConfig, lead_shape, seq_len: int, mesh, lead_spec):
    batch = {
        "tokens": _sds(
            lead_shape + (seq_len,), jnp.int32, mesh,
            P(*lead_spec, *([None] * 1)),
        )
    }
    if cfg.family == "audio":
        d_enc = cfg.encoder_d_model or cfg.d_model
        batch["frames"] = _sds(
            lead_shape + (cfg.encoder_frames, d_enc), jnp.float32, mesh,
            P(*lead_spec, None, None),
        )
    return batch


def cache_specs(bundle: ModelBundle, batch: int, max_len: int, mesh) -> PyTree:
    sds = jax.eval_shape(lambda: bundle.init_cache(batch, max_len))
    return _attach(sds, shd.cache_pspecs(sds, mesh), mesh)


@dataclasses.dataclass(frozen=True)
class DryrunCase:
    """One lowering target: fn(*args) with sharded SDS args."""
    arch: str
    shape: str
    step_kind: str  # fedspd | plain | prefill | decode
    fn: Callable
    args: tuple
    note: str = ""


def build_dryrun(
    arch: str,
    shape_name: str,
    mesh,
    *,
    step_kind: str = "auto",
    attn_mode: str = "blocked",
    gossip_mode: str = "dense",
    remat: bool = True,
    scan_unroll: int = 1,
    n_clusters: int = 2,
    tau: int = 1,
    layout: str = "tp",  # tp | dpc (see below)
    cfg_override: ArchConfig | None = None,
) -> DryrunCase:
    """Assemble (step_fn, sharded input specs) for one dry-run combination."""
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    ok, why = supports_shape(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} × {shape_name}: {why}")
    cfg, note = arch_for_shape(cfg, shape_name)

    # exact cost accounting (two-point trip-count correction, see
    # roofline/analysis.py): the attention pair scan is fully unrolled
    # (exact; block size scaled so the pair count stays compile-tractable)
    # while the layer-stack scan keeps ``scan_unroll`` bodies per iteration —
    # the dry-run compiles at scan_unroll=1 and 2 and extrapolates exactly.
    blk = max(512, shape.seq_len // 16)
    cfg = cfg.with_overrides(
        scan_unroll=scan_unroll, attn_unroll=0,
        attn_q_block=blk, attn_kv_block=blk,
    )

    if step_kind == "auto":
        step_kind = "fedspd" if shape.kind == "train" else shape.kind

    dp = dp_axes(mesh)
    dp_n = dp_size(mesh)

    if step_kind in ("fedspd", "plain"):
        bundle = build_model(cfg, attn_mode=attn_mode, remat=remat)
    else:
        bundle = build_model(cfg, attn_mode=attn_mode, remat=False)

    if step_kind == "fedspd":
        n_clients = dp_n
        per_client = max(1, shape.global_batch // n_clients)
        fcfg = FedSPDConfig(
            n_clients=n_clients, n_clusters=n_clusters, tau=tau,
            batch=per_client, regime="stream",
        )
        n_pods = mesh.shape.get("pod", 1)
        gossip = make_gossip(
            n_clients, n_pods,
            mode="dense" if gossip_mode == "ppermute" else gossip_mode,
        )
        mix_fn = None
        if gossip_mode == "ppermute":
            from repro.launch.steps import make_ppermute_gossip_mix

            p_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
            sel_example = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((n_clients,) + l.shape, l.dtype),
                p_sds,
            )
            mix_fn = make_ppermute_gossip_mix(
                gossip, mesh, sel_example,
                replicate_model_dims=(layout == "dpr"))
        fn = make_fedspd_train_step(bundle, gossip, fcfg, mix_fn=mix_fn)
        state = fedspd_state_specs(
            bundle, fcfg, mesh, replicate_model_dims=(layout == "dpr"))
        # layout "tp"  (paper-faithful baseline): per-client batch lives on
        #   one data row; the client's model is tensor-parallel over "model"
        #   -> per-layer ACTIVATION all-reduces (Megatron-style).
        # layout "dpc" (beyond-paper, §Perf): per-client sequences are
        #   data-parallel over the "model" axis while weights stay sharded
        #   -> XLA inserts per-layer WEIGHT all-gathers + one gradient
        #   reduce-scatter (ZeRO-3-flavoured). For batch*seq >> layer params
        #   this moves orders of magnitude fewer bytes.
        # layout "dpr" (beyond-paper, §Perf iteration 2): like dpc but each
        #   client's weights are fully REPLICATED across the model axis —
        #   all matmuls are local; the only collectives left are the gossip
        #   mix and the per-client gradient mean over its sequence shards.
        #   HBM cost: full param copy per chip (viable for <=2B archs).
        batch_inner = "model" if layout in ("dpc", "dpr") else None
        batch = _token_batch(cfg, (n_clients, per_client), shape.seq_len, mesh,
                             (dp, batch_inner))
        args = (state, batch)
        note = (note + " " if note else "") + (
            f"N={n_clients} clients, {per_client} seq/client, layout={layout}"
        )

    elif step_kind == "plain":
        fn_raw = make_plain_train_step(bundle)
        params = param_specs(bundle, mesh)
        opt = make_optimizer("adamw")
        opt_sds = jax.eval_shape(opt.init, params)
        opt_state = _attach(opt_sds, jax.tree_util.tree_map_with_path(
            lambda p, l: shd.param_spec(p, l.shape, mesh), opt_sds), mesh)
        batch = _token_batch(cfg, (shape.global_batch,), shape.seq_len, mesh,
                             (dp,))
        fn, args = fn_raw, (params, opt_state, batch)

    elif step_kind == "prefill":
        fn = make_prefill_step(bundle)
        params = param_specs(bundle, mesh)
        batch = _token_batch(cfg, (shape.global_batch,), shape.seq_len, mesh,
                             (dp,))
        cache = cache_specs(bundle, shape.global_batch, shape.seq_len, mesh)
        args = (params, batch, cache)

    elif step_kind == "decode":
        fn = make_decode_step(bundle)
        params = param_specs(bundle, mesh)
        cache = cache_specs(bundle, shape.global_batch, shape.seq_len, mesh)
        b_spec = dp if shape.global_batch % dp_n == 0 else None
        tokens = _sds((shape.global_batch, 1), jnp.int32, mesh, P(b_spec, None))
        args = (params, cache, tokens)

    else:
        raise ValueError(f"unknown step kind {step_kind!r}")

    return DryrunCase(
        arch=arch, shape=shape_name, step_kind=step_kind, fn=fn, args=args,
        note=note,
    )


def input_specs(arch: str, shape_name: str, mesh, **kw) -> tuple:
    """Brief-required entry point: sharded ShapeDtypeStructs for every model
    input of this (arch × shape) combination."""
    return build_dryrun(arch, shape_name, mesh, **kw).args
