"""Training launcher: FedSPD over any assigned architecture.

The stream loop CARRIES the packed (S, N, X) parameter plane between
rounds (default): models are packed once after init, every round's step is
jitted with the state donated (the plane is aliased in place, no per-round
copy), and parameters re-enter pytree form only at the final personalize /
checkpoint boundary. ``--pytree`` selects the historical per-leaf engine.
``--scan-rounds`` folds the whole ``--rounds``-round stream into ONE
lax.scan-rolled jitted program (batch sampling traced in the scan body,
per-round metrics returned as scan ys, the plane donated into the single
dispatch) — the launcher-side twin of ``RunConfig(scan_rounds=True)`` on
the registry entry points.

Execution knobs flow through the same ``RunConfig`` the registry entry
points take (experiments/config.py): the argparse flags build one and the
launcher consumes its resolved options, so codec/plane compatibility rules
are enforced by the exact code path ``run_method`` uses.

Two placement modes:

- ``--mesh none`` (default): single-device execution at whatever scale fits
  (smoke configs on CPU; the end-to-end example drivers use this).
- ``--mesh pod|2pod``: the production mesh — the plane's client axis
  sharded over the ("pod","data") rows (one client per row; 16 clients on
  one pod, 32 across two) with gossip running the edge-colored shard_map
  ``ppermute`` schedule. On this CPU container that mesh only exists under
  the dry-run device flag, so ``--mesh`` here is exercised with real
  allocation only on hardware; the sharded *program* is proven by
  launch/dryrun.py and the subprocess tests.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
      --rounds 20 --clients 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.comm import CommConfig, make_channel
from repro.configs.base import ARCH_ALIASES, get_config, get_smoke_config
from repro.core.fedspd import FedSPDConfig, init_state, personalize
from repro.core.gossip import GossipSpec, make_mix_fn
from repro.core.packing import make_pack_spec, pack_state
from repro.core.sparse import SparseConfig, init_masks
from repro.data.synthetic import make_mixture_tokens
from repro.experiments.config import RunConfig
from repro.experiments.heterogeneity import (
    ClientSystemModel,
    apply_client_weights,
    het_round,
    restore_inactive,
)
from repro.graphs.topology import make_graph
from repro.models.registry import build_model
from repro.telemetry import step_annotation, trace_session, write_events


def fl_perplexity(bundle, params_stack, batch) -> float:
    """Mean per-client LM loss of personalized models on held-out batches."""
    pel = jax.vmap(bundle.per_example_loss)(params_stack, batch)
    return float(jnp.mean(pel))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_ALIASES), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--graph", default="er")
    ap.add_argument("--avg-degree", type=float, default=4)
    ap.add_argument("--gossip-mode", default="dense",
                    choices=["dense", "permute"])
    ap.add_argument("--gossip-backend", default="reference",
                    choices=["reference", "pallas"],
                    help="Eq. (1) execution path (mesh mode uses the "
                         "shard_map ppermute schedule regardless)")
    ap.add_argument("--pytree", dest="param_plane", action="store_false",
                    default=True,
                    help="per-leaf pytree state (the pre-plane engine); "
                         "default carries the packed (S, N, X) plane")
    ap.add_argument("--no-donate", dest="donate", action="store_false",
                    default=True,
                    help="disable in-place state donation across rounds")
    ap.add_argument("--scan-rounds", action="store_true",
                    help="roll ALL rounds into one lax.scan-rolled jitted "
                         "program: one compile, one dispatch; per-round "
                         "metrics come back as scan ys")
    ap.add_argument("--mesh", default="none", choices=["none", "pod", "2pod"],
                    help="shard the plane's client axis over the production "
                         "mesh rows (requires the packed plane and one "
                         "client per mesh row)")
    ap.add_argument("--codec", default="fp32",
                    choices=["fp32", "int8", "int4", "topk"],
                    help="wire codec for the exchange (comm/codecs); "
                         "compressing codecs require the packed plane")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry per-client error-feedback residuals")
    ap.add_argument("--codec-block", type=int, default=256,
                    help="quantization-scale block width along X")
    ap.add_argument("--sparse-density", type=float, default=1.0,
                    help="DisPFL sparse training: active fraction of each "
                         "client's parameters (1.0 = dense, off)")
    ap.add_argument("--prune-rate", type=float, default=0.2,
                    help="fraction of active coords cycled per mask update")
    ap.add_argument("--regrow", default="rigl", choices=["rigl", "random"],
                    help="regrow criterion: dense-gradient magnitude (RigL) "
                         "or random")
    ap.add_argument("--mask-update-every", type=int, default=10,
                    help="rounds between RigL prune/regrow mask updates")
    ap.add_argument("--slow-fraction", type=float, default=0.0,
                    help="fraction of clients running at 1/slow-factor "
                         "speed (client heterogeneity)")
    ap.add_argument("--slow-factor", type=float, default=4.0,
                    help="slowdown multiplier for the slow clients")
    ap.add_argument("--time-budget", type=float, default=0.0,
                    help="per-round time budget in nominal round units; "
                         "clients over budget straggle (0 = off)")
    ap.add_argument("--het-jitter", type=float, default=0.0,
                    help="lognormal sigma on per-round compute time")
    ap.add_argument("--p-unavailable", type=float, default=0.0,
                    help="i.i.d. per-round client unavailability")
    ap.add_argument("--staleness-gamma", type=float, default=1.0,
                    help="stale-gossip decay in (0, 1]: sender mixing "
                         "weight scales by gamma**staleness (1 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--telemetry-out", default=None,
                    help="write the run's structured JSONL event log here "
                         "(render with python -m repro.telemetry.summary)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the run into "
                         "this directory (Perfetto-loadable; see "
                         "telemetry/profile.py)")
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--export-servable", default=None,
                    help="also export the consensus cluster plane as a "
                         "servable artifact for launch/serve --artifact")
    ap.add_argument("--export-codec", default="fp32",
                    choices=["fp32", "int8", "int4"],
                    help="plane shipping format for --export-servable")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = build_model(cfg, attn_mode="ref" if args.smoke else "blocked")
    n, s = args.clients, args.clusters

    # one RunConfig carries every execution knob, same as the registry
    # entry points; resolve_options() enforces codec/plane compatibility
    comm = CommConfig(codec=args.codec, block=args.codec_block,
                      error_feedback=args.error_feedback)
    sparse = None
    if args.sparse_density < 1.0:
        try:
            sparse = SparseConfig(
                density=args.sparse_density, prune_rate=args.prune_rate,
                regrow=args.regrow, update_every=args.mask_update_every,
            )
        except ValueError as e:
            raise SystemExit(str(e)) from None
        if args.mesh != "none":
            raise SystemExit(
                "--sparse-density < 1 is not available with --mesh (the "
                "ppermute schedule ships raw plane rows)"
            )
    run_cfg = RunConfig(
        gossip_mode=args.gossip_mode, gossip_backend=args.gossip_backend,
        param_plane=args.param_plane, comm=comm, eval_every=args.eval_every,
        donate=args.donate, scan_rounds=args.scan_rounds, sparse=sparse,
    )
    try:
        opts = run_cfg.resolve_options()
    except ValueError as e:
        raise SystemExit(str(e)) from None

    # client-system heterogeneity (experiments/heterogeneity.py): any of
    # the straggler/availability knobs turns the engine on
    het = None
    if args.time_budget > 0 or args.p_unavailable > 0:
        try:
            het = ClientSystemModel(
                slow_fraction=args.slow_fraction,
                slow_factor=args.slow_factor,
                time_budget=args.time_budget, jitter=args.het_jitter,
                p_unavailable=args.p_unavailable,
                staleness_gamma=args.staleness_gamma, seed=args.seed,
            )
        except ValueError as e:
            raise SystemExit(str(e)) from None

    fcfg = FedSPDConfig(
        n_clients=n, n_clusters=s, tau=args.tau, batch=args.batch,
        lr0=args.lr, regime="stream",
    )
    graph = make_graph(args.graph, n, args.avg_degree, seed=args.seed)
    gossip = GossipSpec.from_graph(graph, mode=opts["mode"])

    key = jax.random.PRNGKey(args.seed)
    k_init, k_data = jax.random.split(key)
    state = init_state(k_init, bundle.init, fcfg, data_m=1)

    # packed plane: pack ONCE here; the loop below carries the (S, N, X)
    # buffer round to round (donated in place) — no re-packing per call
    pack_spec = None
    if opts["param_plane"]:
        pack_spec = make_pack_spec(
            jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        )
        state = pack_state(state, pack_spec)

    # DisPFL masks live on the plane rows; key derivation matches the
    # registry entry points so CLI and run_method agree bit for bit
    if sparse is not None:
        state = state._replace(mask=init_masks(
            jax.random.fold_in(key, 0x3A5C), n, pack_spec.size, sparse))

    # wire codec: the exchange ships encoded payloads; wire_ratio scales
    # the logical comm counter to physical bytes (static per model)
    wire_ratio = 1.0
    channel = None
    if comm.codec != "fp32":
        channel = make_channel(comm, pack_spec.size)
        wire_ratio = channel.wire_ratio(pack_spec.model_bytes)
        if channel.has_ef:
            state = state._replace(ef=channel.init_residual((n,)))
    if sparse is not None and sparse.enabled:
        from repro.comm.codecs import sparse_wire_model_bytes

        x = pack_spec.size
        wire_ratio = (sparse_wire_model_bytes(comm, x, sparse.k_active(x))
                      / float(pack_spec.model_bytes))

    mesh = None
    mix_fn = None
    if args.mesh != "none":
        from repro.launch.mesh import dp_size, make_production_mesh
        from repro.launch.sharding import shard_plane_state

        if pack_spec is None:
            raise SystemExit("--mesh requires the packed plane (drop --pytree)")
        mesh = make_production_mesh(multi_pod=args.mesh == "2pod")
        if dp_size(mesh) != n:
            raise SystemExit(
                f"--mesh {args.mesh} has {dp_size(mesh)} client rows; "
                f"run with --clients {dp_size(mesh)}"
            )
        state = shard_plane_state(state, mesh)
    else:
        mix_fn = make_mix_fn(gossip, opts["gossip_backend"],
                             plane=pack_spec is not None, comm=comm)

    # the heterogeneity wrapper restores inactive plane rows along the
    # client axes — that needs the packed plane, the dense wiring (the
    # permute/ppermute paths read the adjacency as a binary mask), and a
    # single-host plane (the masked where-select is not mesh-aware)
    het_axes = het_key = het_speeds = None
    adj_base = None
    if het is not None:
        if pack_spec is None:
            raise SystemExit(
                "client heterogeneity requires the packed plane "
                "(drop --pytree)"
            )
        if mesh is not None:
            raise SystemExit(
                "client heterogeneity is not available with --mesh "
                "(the ppermute schedule reads a binary adjacency)"
            )
        if opts["mode"] != "dense":
            raise SystemExit(
                "client heterogeneity needs --gossip-mode dense "
                "(stale-gossip weights are real-valued)"
            )
        from repro.core.fedspd import FedSPDState

        het_axes = FedSPDState(
            centers=1, u=0, z=0, round=None, key=None, comm_bytes=None,
            ef=None if state.ef is None else 0,
            mask=None if state.mask is None else 0,
        )
        het_key = jax.random.fold_in(jax.random.PRNGKey(args.seed), 0x51AC)
        het_speeds = jnp.asarray(het.resolve_speeds(n))
        adj_base = jnp.asarray(graph.adj, jnp.float32)

    from repro.launch.steps import make_fedspd_train_step

    # scan mode traces the raw step into one whole-run program and donates
    # the state there instead of per dispatch; the het wrapper likewise
    # owns the jit boundary (old and new plane meet in its where-select)
    inner_donate = (run_cfg.donate and not run_cfg.scan_rounds
                    and het is None)
    step = make_fedspd_train_step(
        bundle, gossip, fcfg, mix_fn=mix_fn, pack_spec=pack_spec,
        mesh=mesh, donate=inner_donate, comm=comm, sparse=sparse,
    )
    if het is not None:
        def het_step(st, batch, r, hc):
            hc, aw = het_round(het, het_speeds, hc,
                               jax.random.fold_in(het_key, r))
            new, metrics = step(st, batch, adj=apply_client_weights(
                adj_base, aw))
            return restore_inactive(st, new, het_axes, aw > 0.0), hc, \
                metrics

        if not run_cfg.scan_rounds:
            het_step = jax.jit(
                het_step, donate_argnums=(0,) if run_cfg.donate else ())
    elif not run_cfg.donate and not run_cfg.scan_rounds:
        step = jax.jit(step)

    # document pool: cluster-specific Markov chains (paper's mixture analogue)
    pool = make_mixture_tokens(
        n_clients=n, n_clusters=s, docs_per_client=max(32, 4 * args.batch),
        seq_len=args.seq, vocab=min(cfg.vocab, 512), seed=args.seed,
    )
    docs = jnp.asarray(pool["tokens"])  # (N, D, L)

    def sample_batch(k):
        # traceable (static shapes only): the scan body samples in-program
        idx = jax.random.randint(k, (n, args.batch), 0, docs.shape[1])
        batch = {"tokens": jnp.take_along_axis(docs, idx[:, :, None], axis=1)}
        if cfg.family == "audio":
            d_enc = cfg.encoder_d_model or cfg.d_model
            batch["frames"] = jnp.zeros(
                (n, args.batch, cfg.encoder_frames or 16, d_enc), jnp.float32)
        return batch

    print(f"FedSPD: arch={cfg.name} N={n} S={s} graph={args.graph} "
          f"deg={graph.avg_degree:.1f} gossip={opts['mode']} "
          f"true-mix[0]={pool['mix_true'][0].round(2)}")
    t0 = time.time()
    het_carry = het.init_carry(n) if het is not None else None
    telem_rounds = []   # per-round event rows when --telemetry-out

    def round_row(lr, consensus, logical):
        return {"lr": float(lr), "consensus": np.asarray(consensus),
                "logical_bytes": float(logical),
                "wire_bytes": float(logical) * wire_ratio}

    with trace_session(args.profile_dir):
        if run_cfg.scan_rounds:
            def body(carry, x):
                st, k, hc = carry
                k, kb = jax.random.split(k)
                if het is not None:
                    st, hc, metrics = het_step(st, sample_batch(kb), x, hc)
                else:
                    st, metrics = step(st, sample_batch(kb))
                return (st, k, hc), metrics

            def program(st, k, hc):
                # the round index rides the xs only when the heterogeneity
                # stream needs fold_in(round); hc is None otherwise and the
                # compiled program is unchanged
                xs = (jnp.arange(args.rounds, dtype=jnp.int32)
                      if het is not None else None)
                return jax.lax.scan(body, (st, k, hc), xs=xs,
                                    length=args.rounds)

            runner = jax.jit(
                program, donate_argnums=(0,) if run_cfg.donate else ())
            (state, k_data, het_carry), tape = runner(state, k_data,
                                                      het_carry)
            tape = jax.tree.map(np.asarray, tape)
            for r in range(args.rounds):
                if args.telemetry_out:
                    telem_rounds.append(round_row(
                        tape["lr"][r], tape["consensus"][r],
                        tape["comm_bytes"][r]))
                if r % run_cfg.eval_every == 0 or r == args.rounds - 1:
                    logical = float(tape["comm_bytes"][r])
                    print(f"round {r:4d}  lr={float(tape['lr'][r]):.4f}  "
                          f"consensus={tape['consensus'][r]}  "
                          f"comm={logical:.3e}B  "
                          f"wire={logical * wire_ratio:.3e}B")
            print(f"scan-rolled: {args.rounds} rounds in one compiled "
                  f"program, one dispatch ({time.time() - t0:.1f}s)")
        else:
            for r in range(args.rounds):
                k_data, kb = jax.random.split(k_data)
                with step_annotation("repro/round", r):
                    if het is not None:
                        state, het_carry, metrics = het_step(
                            state, sample_batch(kb), r, het_carry)
                    else:
                        state, metrics = step(state, sample_batch(kb))
                if args.telemetry_out:
                    telem_rounds.append(round_row(
                        metrics["lr"], metrics["consensus"],
                        metrics["comm_bytes"]))
                if r % run_cfg.eval_every == 0 or r == args.rounds - 1:
                    cons = np.asarray(metrics["consensus"])
                    logical = float(metrics["comm_bytes"])
                    print(f"round {r:4d}  lr={float(metrics['lr']):.4f}  "
                          f"consensus={cons}  comm={logical:.3e}B  "
                          f"wire={logical * wire_ratio:.3e}B  "
                          f"({time.time()-t0:.1f}s)")

    personalized = personalize(state, pack_spec)  # pytree re-entry boundary
    k_data, kb = jax.random.split(k_data)
    eval_batch = sample_batch(kb)
    final_loss = fl_perplexity(bundle, personalized, eval_batch)
    print("final mean per-client loss (personalized Eq.2): "
          f"{final_loss:.4f}")
    if args.telemetry_out:
        last_logical = (telem_rounds[-1]["logical_bytes"]
                        if telem_rounds else 0.0)
        events = [{
            "event": "run_meta", "method": "fedspd", "arch": cfg.name,
            "rounds": args.rounds, "n_clients": n, "n_clusters": s,
            "seed": args.seed, "codec": comm.codec,
            "streams": sorted(("lr", "consensus", "logical_bytes",
                               "wire_bytes")),
        }]
        events += [{"event": "round", "round": r, **row}
                   for r, row in enumerate(telem_rounds)]
        summary = {"event": "summary", "final_loss": final_loss,
                   "comm_bytes": last_logical,
                   "wire_bytes": last_logical * wire_ratio,
                   "wall_s": time.time() - t0}
        if het is not None:
            summary["staleness"] = np.asarray(het_carry.stale)
        events.append(summary)
        write_events(args.telemetry_out, events)
        print(f"telemetry -> {args.telemetry_out} "
              f"({len(telem_rounds)} round events)")
    print(f"mixture coefficients u:\n{np.asarray(state.u).round(3)}")
    if het is not None:
        print(f"final staleness (rounds since last exchange): "
              f"{np.asarray(het_carry.stale)}")
    if args.save:
        ckpt.save(
            args.save, {"personalized": personalized, "u": state.u},
            manifest=ckpt.CkptManifest(
                kind="checkpoint", arch=cfg.name, n_clients=n, n_clusters=s,
                pack_digest=pack_spec.digest if pack_spec else None,
            ),
        )
        print(f"saved -> {args.save}")
    if args.export_servable:
        from repro.experiments.export import export_servable

        spec = pack_spec or make_pack_spec(
            jax.eval_shape(bundle.init, jax.random.PRNGKey(0)))
        export_servable(state, spec, args.export_servable, arch=cfg.name,
                        codec=args.export_codec,
                        qblock=max(2, args.codec_block // 2 * 2))
        print(f"servable plane -> {args.export_servable} "
              f"({args.export_codec})")


if __name__ == "__main__":
    main()
