"""Production mesh construction (TPU v5e target).

All mesh building lives behind functions so importing this module never
touches jax device state (the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* jax
initializes; see launch/dryrun.py line 1).

Axes:
  single-pod : (16, 16)        -> ("data", "model")    256 chips
  multi-pod  : (2, 16, 16)     -> ("pod", "data", "model")  512 chips

FedSPD mapping (DESIGN.md §2): one FL *client* per data-axis row — 16
clients on one pod, 32 across two pods. Within a client, parameters and
activations are tensor-parallel over "model". The gossip graph is generated
pod-aware: dense intra-pod (ICI), sparse bridges inter-pod (DCN).
"""
from __future__ import annotations

import jax
import numpy as np

# --- TPU v5e hardware constants (per chip), used by roofline/ ---
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
HBM_BYTES = 16 * 2**30       # 16 GiB HBM per chip


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            "visible — run through launch/dryrun.py, which forces "
            "--xla_force_host_platform_device_count=512 before jax init"
        )
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for unit tests (honours whatever device count exists)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple:
    """The axes a batch/client dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def model_size(mesh: jax.sharding.Mesh) -> int:
    return int(mesh.shape["model"])


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
