import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)  # MUST precede any jax import — jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) combination on a 512-placeholder-device host mesh, print
memory_analysis / cost_analysis, and emit the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 pairs
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results are appended to experiments/dryrun/*.json for EXPERIMENTS.md.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import ARCH_ALIASES, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh, n_chips
from repro.launch.specs import build_dryrun
from repro.launch.steps import supports_shape
from repro.roofline import analysis as rl

CANONICAL_ARCHS = [
    "olmo-1b", "olmoe-1b-7b", "phi3.5-moe-42b-a6.6b", "whisper-base",
    "h2o-danube-1.8b", "zamba2-1.2b", "gemma3-1b", "granite-3-8b",
    "mamba2-370m", "chameleon-34b",
]


def run_case(arch: str, shape_name: str, mesh, mesh_name: str, *,
             step_kind: str = "auto", attn_mode: str = "blocked",
             gossip_mode: str = "dense", remat: bool = True,
             layout: str = "tp", moe_dispatch: str | None = None,
             single_compile: bool = False, verbose: bool = True):
    """Lower + compile one combination; return (Roofline, wall_seconds)."""
    cfg = get_config(arch)
    ok, why = supports_shape(cfg, shape_name)
    if not ok:
        return None, why
    cfg_override = (
        cfg.with_overrides(moe_dispatch=moe_dispatch) if moe_dispatch else None
    )

    t0 = time.time()
    compiled = {}
    # two-point trip-count correction (roofline/analysis.py); multi-pod
    # sweeps prove sharding only (single compile, roofline is single-pod)
    unrolls = (1,) if single_compile else (1, 2)
    for u in unrolls:
        case = build_dryrun(
            arch, shape_name, mesh, step_kind=step_kind, attn_mode=attn_mode,
            gossip_mode=gossip_mode, remat=remat, scan_unroll=u,
            layout=layout, cfg_override=cfg_override,
        )
        with mesh:
            lowered = jax.jit(case.fn).lower(*case.args)
            compiled[u] = lowered.compile()
    wall = time.time() - t0

    shape = INPUT_SHAPES[shape_name]
    cfg_eff, _ = __import__("repro.launch.steps", fromlist=["arch_for_shape"]
                            ).arch_for_shape(get_config(arch), shape_name)
    mf = rl.model_flops_for(get_config(arch), shape, case.step_kind)
    roof = rl.analyze_two_point(
        arch=arch, shape=shape_name, step_kind=case.step_kind,
        mesh_name=mesh_name, chips=n_chips(mesh),
        compiled1=compiled[1], compiled2=compiled.get(2, compiled[1]),
        ratio=0.0 if single_compile else rl.scan_trip_ratio(cfg_eff),
        model_flops=mf,
        note=case.note + (" [single-compile: uncorrected]" if single_compile
                          else ""),
    )
    if verbose:
        print(f"--- {arch} × {shape_name} × {mesh_name} [{case.step_kind}] "
              f"({wall:.1f}s compile) {case.note}")
        print(f"    memory_analysis: {roof.memory_per_chip}")
        print(f"    cost_analysis: flops/chip={roof.flops_per_chip:.3e} "
              f"bytes/chip={roof.bytes_per_chip:.3e}")
        print(f"    collectives/chip: {roof.coll_breakdown}")
        print(f"    roofline: compute={roof.compute_s:.3e}s "
              f"memory={roof.memory_s:.3e}s coll={roof.collective_s:.3e}s "
              f"-> {roof.bottleneck}-bound, useful={roof.useful_ratio:.3f}")
    return roof, wall


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCH_ALIASES), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true", help="all 10×4 pairs")
    ap.add_argument("--multi-pod", action="store_true",
                    help="(2,16,16) pod mesh instead of (16,16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--step", default="auto",
                    choices=["auto", "fedspd", "plain", "prefill", "decode"])
    ap.add_argument("--attn-mode", default="blocked",
                    choices=["blocked", "ref", "pallas"])
    ap.add_argument("--gossip-mode", default="dense",
                    choices=["dense", "permute", "ppermute"])
    ap.add_argument("--layout", default="tp", choices=["tp", "dpc", "dpr"])
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["cumsum", "sort", "grouped"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--single-compile", action="store_true",
                    help="skip the unroll=2 compile (sharding proof only)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    meshes = []
    if args.both_meshes:
        meshes = [(make_production_mesh(), "pod16x16"),
                  (make_production_mesh(multi_pod=True), "2pod")]
    elif args.multi_pod:
        meshes = [(make_production_mesh(multi_pod=True), "2pod")]
    else:
        meshes = [(make_production_mesh(), "pod16x16")]

    if args.all:
        pairs = [(a, s) for a in CANONICAL_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch+--shape or --all"
        pairs = [(args.arch, args.shape)]

    rows, failures, skips = [], [], []
    for mesh, mesh_name in meshes:
        for arch, shape_name in pairs:
            try:
                roof, wall = run_case(
                    arch, shape_name, mesh, mesh_name, step_kind=args.step,
                    attn_mode=args.attn_mode, gossip_mode=args.gossip_mode,
                    remat=not args.no_remat, layout=args.layout,
                    moe_dispatch=args.moe_dispatch,
                    single_compile=args.single_compile,
                )
            except Exception:
                print(f"!!! FAILED {arch} × {shape_name} × {mesh_name}")
                traceback.print_exc()
                failures.append((arch, shape_name, mesh_name))
                continue
            if roof is None:
                print(f"--- SKIP {arch} × {shape_name}: {wall}")
                skips.append((arch, shape_name, wall))
                continue
            rows.append(roof)
            tag = f"{arch}__{shape_name}__{mesh_name}".replace(".", "_")
            if args.layout != "tp":
                tag += f"__{args.layout}"
            if args.gossip_mode != "dense":
                tag += f"__{args.gossip_mode}"
            if args.moe_dispatch:
                tag += f"__{args.moe_dispatch}"
            if args.no_remat:
                tag += "__noremat"
            with open(outdir / f"{tag}.json", "w") as f:
                json.dump(roof.to_json(), f, indent=1)

    print()
    print(rl.format_table(rows))
    if skips:
        print(f"\nskipped ({len(skips)}):")
        for a, s, why in skips:
            print(f"  {a} × {s}: {why}")
    if failures:
        print(f"\nFAILURES ({len(failures)}): {failures}")
        raise SystemExit(1)
    print(f"\nall {len(rows)} combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
