"""Production step functions: FedSPD train round, plain-DP train, serve.

The paper's technique is the framework's first-class training mode:
``train_step`` is one FedSPD round (stream regime — Section 4's four steps
over one fresh per-client batch) with the client axis mapped onto the mesh's
("pod","data") rows and each client's model tensor-parallel over "model".

``plain`` is the conventional fully-synchronous data-parallel step — the
non-personalized reference point used in the roofline comparison (what the
paper calls DFL-FedAvg collapses to this on a fully-connected graph).

Serve steps realize deliverable shapes: ``prefill`` fills the KV/SSM cache
for a personalized model; ``decode`` generates ONE token against a
seq_len-deep cache (decode_32k, long_500k).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.fedspd import FedSPDConfig, make_round_step
from repro.core.gossip import GossipSpec
from repro.graphs.topology import pod_aware
from repro.models.registry import ModelBundle
from repro.optim.sgd import make_optimizer

PyTree = Any


def make_gossip(n_clients: int, n_pods: int, seed: int = 0,
                mode: str = "dense") -> GossipSpec:
    """Pod-aware client graph: dense ER intra-pod (ICI), sparse bridges
    inter-pod (DCN)."""
    graph = pod_aware(n_clients // n_pods, n_pods, seed=seed)
    return GossipSpec.from_graph(graph, mode=mode)


def make_fedspd_train_step(
    bundle: ModelBundle,
    gossip: GossipSpec,
    fcfg: FedSPDConfig,
    mix_fn=None,
    pack_spec=None,
    mesh=None,
    donate: bool = False,
    comm=None,
    sparse=None,
):
    """One FedSPD round over (N_clients, per_client_batch, ...) batches.

    ``pack_spec`` (core/packing.py) selects the packed (S, N, X)
    parameter-plane engine; the per-model wire bytes are derived once here
    (static per model) instead of per-trace inside the step body.

    ``mesh`` (requires the packed plane) is the multi-host path: the
    plane's client axis is sharded over the mesh's ("pod","data") rows
    (launch/sharding.plane_state_pspecs) and the gossip runs the
    edge-colored ``lax.ppermute`` schedule under shard_map — place the
    state with ``sharding.shard_plane_state`` and GSPMD keeps it there.
    ``donate=True`` jits the step with the state donated, so the plane is
    updated in place round over round (no per-round copy of the largest
    buffer in the program). ``comm`` (comm/codecs.CommConfig) runs the
    exchange through a wire codec — on the mesh path the ppermute
    schedule ships the ENCODED payload over the collective edges.
    ``sparse`` (core/sparse.SparseConfig) runs the DisPFL masked round —
    requires the packed plane, incompatible with the mesh/ppermute path
    (the collective schedule ships raw plane rows)."""
    if sparse is not None and sparse.enabled and mesh is not None:
        raise ValueError(
            "sparse training is not available on the mesh/ppermute path — "
            "the collective schedule ships raw plane rows, not masked "
            "payloads"
        )
    model_bytes = None
    if getattr(bundle, "init", None) is not None:
        from repro.utils.pytree import tree_bytes

        p_sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        model_bytes = tree_bytes(p_sds)
    if mesh is not None:
        if pack_spec is None:
            raise ValueError(
                "mesh sharding of the round step requires the packed "
                "parameter plane (pass pack_spec)"
            )
        if mix_fn is None:
            mix_fn = make_ppermute_gossip_mix(
                gossip, mesh, replicate_model_dims=True, comm=comm
            )
    step = make_round_step(
        bundle.loss, bundle.per_example_loss, gossip, fcfg, mix_fn=mix_fn,
        pack_spec=pack_spec, model_bytes=model_bytes, donate=donate,
        comm=comm, sparse=sparse,
    )

    def train_step(state, batch, adj=None):
        # adj: the scenario/heterogeneity engines' traced per-round
        # adjacency (core/fedspd.make_round_step); None keeps the
        # static-graph program bit for bit
        if adj is None:
            return step(state, batch)
        return step(state, batch, adj=adj)

    return train_step


def make_plain_train_step(bundle: ModelBundle, optimizer_name: str = "adamw",
                          lr: float = 3e-4):
    """Synchronous data-parallel LM training step (reference point)."""
    opt = make_optimizer(optimizer_name)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, loss

    return train_step


def make_prefill_step(bundle: ModelBundle):
    """Fill the cache for a request batch (the LM-head matmul on the full
    sequence is dead code and DCE'd — prefill cost is attention + FFN)."""

    def prefill_step(params, batch, cache):
        return bundle.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(bundle: ModelBundle):
    """One new token against a seq_len-deep cache."""

    def decode_step(params, cache, tokens):
        return bundle.decode_step(params, cache, tokens)

    return decode_step


def arch_for_shape(cfg: ArchConfig, shape_name: str) -> tuple[ArchConfig, str]:
    """Shape-level arch adaptation (DESIGN.md §Arch-applicability).

    long_500k requires sub-quadratic attention: pure full-attention archs run
    it under an explicit sliding-window (4096) variant; whisper skips (the
    caller checks ``supports_shape`` first). Returns (cfg, note)."""
    if shape_name != "long_500k":
        return cfg, ""
    if cfg.supports_long_context:
        return cfg, "native sub-quadratic"
    return cfg.with_overrides(window=4096), "+swa4096 variant"


def supports_shape(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family == "audio":
        return False, (
            "skip: enc-dec audio backbone (1500-frame encoder); a 500k-token "
            "decode has no audio meaning (DESIGN.md §4)"
        )
    return True, ""


def make_ppermute_gossip_mix(gossip: GossipSpec, mesh, state_example=None,
                             replicate_model_dims: bool = False,
                             comm=None):
    """FedSPD's Eq. (1) as an explicit edge-colored ``lax.ppermute`` schedule
    under shard_map (§Perf H1 iter 2 found that ``jnp.take`` along the
    client axis does NOT lower to collective_permute under GSPMD — this is
    the real collective schedule, one permute per color class, bytes ∝ deg·X
    per client instead of the dense einsum's all-gather ∝ N·X).

    Requires exactly one client per ("pod","data") mesh row (the production
    mapping). ``state_example`` provides the selected-center pytree SDS so
    per-leaf shard_map specs can be derived once; when omitted (the
    registry path — core/gossip.make_mix_fn backend="ppermute") the specs
    are derived at trace time from the actual ``c_sel`` argument, which
    also makes the schedule polymorphic over pytree and packed-plane
    inputs.

    ``comm`` (comm/codecs.CommConfig, any codec other than fp32) switches
    the schedule to ENCODED payloads: the sender's packed (N, X) slab is
    encoded once outside the shard_map, the per-color ``lax.ppermute``
    moves the encoded leaves (int8 quanta + per-block scales, or top-k
    value/index pairs — the compressed bytes are what crosses the
    interconnect), and each receiver dequantizes locally. The receiver's
    OWN contribution also goes through the codec, so the result equals
    the dense comm path's W·decode(encode(C)) exactly (parity-tested).
    The returned fn is comm-aware: ``(c_sel, s, key, ef) -> (mixed, ef')``.

    Both variants accept ``adj=``: this round's TRACED (N, N) adjacency
    (the scenario engine's dynamic topologies). The collective schedule
    stays static — built from the spec's (union-graph) edge coloring — and
    the traced matrix masks inactive edges inside the shard_map body, so a
    dropped/rewired-away link contributes nothing to the average. The
    traced adjacency must therefore be a subgraph of ``gossip.adj``.
    """
    import numpy as np

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch import sharding as shd
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    n = gossip.adj.shape[0]

    # static per-color (src -> dst) pairs, matched masks, and the partner
    # index vector (the latter resolves a traced per-round adjacency's
    # edge-activity bit inside the shard_map body)
    colors = []
    for perm in gossip.perms:
        perm = np.asarray(perm)
        pairs = tuple(
            (int(i), int(perm[i])) for i in range(n) if perm[i] != i
        )
        if pairs:
            colors.append((pairs, jnp.asarray(perm != np.arange(n)),
                           jnp.asarray(perm)))

    def leaf_spec(path, leaf):
        # MUST match the layout's center sharding exactly — a mismatched
        # shard_map boundary makes GSPMD reshard the full parameter set
        # (measured: collective term 1.96 s -> 8.03 s on olmo-1b/dpr)
        if replicate_model_dims:
            inner = P(*([None] * (len(leaf.shape) - 1)))
        else:
            inner = shd.param_spec(path, leaf.shape[1:], mesh)
        return P(dp, *inner)

    def build_specs(tree):
        return jax.tree_util.tree_map_with_path(
            lambda pth, l: leaf_spec(pth, l), tree
        )

    c_specs = build_specs(state_example) if state_example is not None else None
    axis = dp if len(dp) > 1 else dp[0]

    def _adj_operand(adj):
        """The optional traced-adjacency operand: row-sharded over the
        client axis when dynamic, absent (not a replicated dummy —
        identical static program) otherwise."""
        return ((), ()) if adj is None else ((P(dp, None),), (adj,))

    if comm is not None and comm.codec != "fp32":
        from repro.comm.codecs import make_channel

        def mix_fn_comm(c_sel, s, key, ef, adj=None):
            ch = make_channel(comm, c_sel.shape[-1])
            enc, _x_hat, ef = ch.encode_stream(c_sel, key, ef)
            enc_specs = build_specs(enc)

            def body(enc_loc, s_loc, a_loc=None):
                idx = jax.lax.axis_index(dp[-1])
                if len(dp) > 1:
                    idx = idx + jax.lax.axis_index(dp[0]) * mesh.shape[dp[-1]]
                # own contribution decodes the own ENCODED message so the
                # result matches the dense path's W·decode(encode(C))
                acc = ch.decode(enc_loc)          # (1, X) fp32
                cnt = jnp.ones((1,), jnp.float32)
                for pairs, matched, perm in colors:
                    recv_s = jax.lax.ppermute(s_loc, axis, pairs)
                    recv_enc = jax.tree.map(
                        lambda l: jax.lax.ppermute(l, axis, pairs), enc_loc
                    )
                    m = (recv_s == s_loc) & matched[idx]
                    if a_loc is not None:
                        # this round's traced adjacency row: the permute
                        # still runs (static schedule) but a dropped edge
                        # contributes nothing to the average
                        m &= a_loc[0, perm[idx]] > 0
                    mf = m.astype(jnp.float32)
                    acc = acc + mf[:, None] * ch.decode(recv_enc)
                    cnt = cnt + mf
                return acc / cnt[:, None]

            adj_specs, adj_args = _adj_operand(adj)
            fn = shard_map(
                body,
                mesh=mesh,
                in_specs=(enc_specs, P(dp)) + adj_specs,
                out_specs=P(dp, None),
            )
            return fn(enc, s, *adj_args).astype(c_sel.dtype), ef

        mix_fn_comm.comm_aware = True
        return mix_fn_comm

    def mix_fn(c_sel, s, adj=None):
        specs = c_specs if c_specs is not None else build_specs(c_sel)
        def body(c_loc, s_loc, a_loc=None):
            # c_loc leaves (1, X_shard...); s_loc (1,); a_loc (1, N) — the
            # client's row of this round's traced adjacency (when dynamic)
            idx = jax.lax.axis_index(dp[-1])
            if len(dp) > 1:
                idx = idx + jax.lax.axis_index(dp[0]) * mesh.shape[dp[-1]]
            acc = jax.tree.map(lambda l: l.astype(jnp.float32), c_loc)
            cnt = jnp.ones((1,), jnp.float32)
            for pairs, matched, perm in colors:
                recv_s = jax.lax.ppermute(s_loc, axis, pairs)
                recv_c = jax.tree.map(
                    lambda l: jax.lax.ppermute(l, axis, pairs), c_loc
                )
                m = (recv_s == s_loc) & matched[idx]
                if a_loc is not None:
                    m &= a_loc[0, perm[idx]] > 0
                mf = m.astype(jnp.float32)
                acc = jax.tree.map(
                    lambda a, r: a + mf.reshape((-1,) + (1,) * (r.ndim - 1))
                    * r.astype(jnp.float32),
                    acc, recv_c,
                )
                cnt = cnt + mf
            return jax.tree.map(
                lambda a, l: (a / cnt.reshape((-1,) + (1,) * (a.ndim - 1))
                              ).astype(l.dtype),
                acc, c_loc,
            ), None

        adj_specs, adj_args = _adj_operand(adj)
        fn = shard_map(
            lambda c, sv, *a: body(c, sv, *a)[0],
            mesh=mesh,
            in_specs=(specs, P(dp)) + adj_specs,
            out_specs=specs,
        )
        return fn(c_sel, s, *adj_args)

    return mix_fn
