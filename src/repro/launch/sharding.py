"""Sharding rules: pytree -> PartitionSpec trees for the production mesh.

Strategy (DESIGN.md §2):

- **Parameters** are tensor-parallel over "model". Rules are path-aware:
  MoE expert tensors shard the expert dim (expert parallelism); embeddings
  shard the vocab dim; everything else shards the largest dim divisible by
  the model-axis size (preferring the last = output-features dim on ties).
  Leaves under a scanned stack ("layers", "enc_layers", ...) skip the
  leading (L,) axis. Small leaves (norm scales, routers) stay replicated.

- **FedSPD state**: cluster-center leaves are (S, N_clients, *param_shape);
  the client axis shards over ("pod","data") and the inner dims reuse the
  parameter rule. u/z shard their client axis; scalars replicate.

- **Batches**: leading batch/client dim over ("pod","data").

- **KV / SSM caches**: batch dim over data when divisible, else the cache
  length dim; heads over "model" when divisible, else the cache length dim
  (flash-decoding-style sequence sharding — decode_attention's (m, l, o)
  partials make the combine exact).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, model_size

PyTree = Any

# leaves whose total size is below this stay replicated (norm scales, biases)
_MIN_SHARD_ELEMS = 1 << 16

# containers whose children carry a leading scanned (n_layers,) axis
_STACKED = ("layers", "enc_layers", "dec_layers", "mamba_layers")


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    ).lower()


def _generic_model_dim(shape, start: int, m: int):
    """Largest dim in shape[start:] divisible by the model-axis size
    (ties -> later dim). None if nothing divides."""
    best, best_size = None, 0
    for d in range(start, len(shape)):
        if shape[d] % m == 0 and shape[d] >= m and shape[d] >= best_size:
            best, best_size = d, shape[d]
    return best


def param_spec(path, leaf_shape, mesh: Mesh) -> P:
    """PartitionSpec for one model-parameter leaf."""
    m = model_size(mesh)
    name = _path_str(path)
    skip = 1 if any(s in name for s in _STACKED) else 0
    spec = [None] * len(leaf_shape)
    if int(np.prod(leaf_shape)) < _MIN_SHARD_ELEMS:
        return P(*spec)
    # MoE expert tensors: expert-parallel over "model"
    if any(k in name for k in ("w_in", "w_out", "w_gate")) and len(leaf_shape) >= 3:
        e_dim = skip  # (L, E, D, F) or (E, D, F)
        if leaf_shape[e_dim] % m == 0:
            spec[e_dim] = "model"
            return P(*spec)
        # fall through to generic if experts don't divide
    d = _generic_model_dim(leaf_shape, skip, m)
    if d is not None:
        spec[d] = "model"
    return P(*spec)


def params_pspecs(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf.shape, mesh), params
    )


def params_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), params_pspecs(params, mesh)
    )


# --------------------------------------------------------------------------
# FedSPD state: centers (S, N, ...), u (N, S), z (N, M), scalars
# --------------------------------------------------------------------------


def fedspd_state_pspecs(state, mesh: Mesh):
    """PartitionSpecs for a FedSPDState: pytree centers with leaves
    (S, N_clients, *param_shape), or the packed (S, N, X) plane
    (dispatches to ``plane_state_pspecs``)."""
    if hasattr(state.centers, "ndim"):  # packed plane, not a pytree
        return plane_state_pspecs(state, mesh)
    dp = dp_axes(mesh)

    def center_spec(path, leaf):
        inner = param_spec(path, leaf.shape[2:], mesh)
        return P(None, dp, *inner)

    centers = jax.tree_util.tree_map_with_path(center_spec, state.centers)
    return type(state)(
        centers=centers,
        u=P(dp, None),
        z=P(dp, None),
        round=P(),
        key=P(),
        comm_bytes=P(),
    )


def plane_state_pspecs(state, mesh: Mesh):
    """PartitionSpecs for a FedSPDState carrying the packed (S, N, X)
    parameter plane: the client (N) axis shards over the mesh's
    ("pod","data") rows — one client per row, matching the edge-colored
    ppermute gossip schedule — and the flat X axis stays replicated
    (sharding it over "model" would cut across the PackSpec's static leaf
    offsets; tensor-parallel model dims live INSIDE the per-client forward,
    not on the plane). u and z shard their client axis, and so does the
    (N, X) error-feedback residual when a compressing codec carries one
    (``state.ef`` is None otherwise — an empty subtree with no spec)."""
    dp = dp_axes(mesh)
    return type(state)(
        centers=P(None, dp, None),
        u=P(dp, None),
        z=P(dp, None),
        round=P(),
        key=P(),
        comm_bytes=P(),
        ef=None if state.ef is None else P(dp, None),
    )


def shard_plane_state(state, mesh: Mesh):
    """Place a packed FedSPDState on the mesh (client axis over rows) —
    the one device_put the stream loop does before carrying the plane
    donated round to round."""
    return jax.device_put(
        state, to_shardings(plane_state_pspecs(state, mesh), mesh)
    )


# --------------------------------------------------------------------------
# Batches
# --------------------------------------------------------------------------


def batch_pspecs(batch: PyTree, mesh: Mesh) -> PyTree:
    """Leading dim (global batch or client axis) over ("pod","data")."""
    dp = dp_axes(mesh)
    return jax.tree.map(lambda l: P(dp, *([None] * (l.ndim - 1))), batch)


# --------------------------------------------------------------------------
# Decode caches
# --------------------------------------------------------------------------


def _cache_leaf_spec(name: str, shape, mesh: Mesh) -> P:
    """KV cache leaves (Lay, B, Lc, Hkv, hd); SSM state (Lay, B, H, P, N);
    conv state (Lay, B, w, D); cross-KV (Lay, B, Lenc, H, hd); pos ()."""
    dp = dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    m = model_size(mesh)
    spec = [None] * len(shape)
    if len(shape) == 0 or int(np.prod(shape)) < _MIN_SHARD_ELEMS:
        return P(*spec)

    b_dim = 1 if len(shape) >= 2 else None  # leading dim is the layer stack
    big_dim = 2 if len(shape) >= 3 else None  # cache length / heads / width

    # data axes: batch if divisible, else the big cache dim
    if b_dim is not None and shape[b_dim] % dp_n == 0 and shape[b_dim] >= dp_n:
        spec[b_dim] = dp
        seq_data = False
    elif big_dim is not None and shape[big_dim] % dp_n == 0:
        spec[big_dim] = dp
        seq_data = True
    else:
        seq_data = False

    # model axis: heads dim if present & divisible, else head_dim, else length
    if len(shape) == 5:  # (Lay, B, Lc, Hkv, hd) or (Lay, B, H, P, N) ssm state
        if shape[3] % m == 0:
            spec[3] = "model"
        elif shape[4] % m == 0:
            spec[4] = "model"
        elif not seq_data and shape[2] % m == 0:
            spec[2] = "model"
        elif seq_data and shape[2] % (dp_n * m) == 0:
            spec[2] = dp + ("model",)
    elif len(shape) == 4:  # (Lay, B, w, D) conv state
        if shape[3] % m == 0:
            spec[3] = "model"
    return P(*spec)


def cache_pspecs(cache: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(_path_str(path), leaf.shape, mesh),
        cache,
    )


def to_shardings(pspecs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def sds_with_sharding(tree_sds: PyTree, pspecs: PyTree, mesh: Mesh) -> PyTree:
    """Attach NamedShardings to a tree of ShapeDtypeStructs."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree_sds,
        pspecs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )
