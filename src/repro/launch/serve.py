"""Serving launcher: batched generation from a personalized FedSPD model.

After FedSPD training each client owns a personalized model x_i (Eq. 2 +
final local epochs). This driver serves one such model: prefill a batch of
requests, then decode tokens autoregressively. On the production mesh,
weights are tensor-parallel over "model" and requests data-parallel over
("pod","data"); the compiled program for the big shapes is proven by
launch/dryrun.py (decode_32k / long_500k lower serve_step, not train_step).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ARCH_ALIASES, get_config, get_smoke_config
from repro.models.registry import build_model


def generate(bundle, params, prompt_tokens, *, gen_len: int, max_len: int,
             frames=None, temperature: float = 0.0, key=None):
    """Prefill + greedy/temperature decode. Returns (B, gen_len) tokens."""
    # the audio family's prefill does NOT consume the prompt
    # (encdec_prefill_cross only fills cross-attention K/V, pos stays 0):
    # fail loudly before paying the prefill compile instead of decoding
    # against an empty self-attention cache (the old dynamic pos check
    # made this path die later with an undefined `logits`)
    if frames is not None:
        raise NotImplementedError(
            "audio serving needs a decoder prefill over the prompt tokens "
            "(encdec_prefill_cross only fills the cross-attention cache); "
            "use launch/dryrun.py's serve shapes for audio"
        )
    cfg = bundle.cfg
    b, lp = prompt_tokens.shape
    cache = bundle.init_cache(b, max_len)
    cache = jax.jit(bundle.prefill)(params, {"tokens": prompt_tokens}, cache)

    # first generated token comes from the last prompt logits: the LM
    # bundles' prefill consumes the full prompt WITHOUT emitting logits
    # (pos lands at lp by construction — a static property of the model
    # bundles, not runtime data), so the first token always comes from
    # re-scoring the last prompt token. Reading the device value back with
    # `int(cache["pos"])` here blocked the host on the entire prefill
    # before the first decode step could even be enqueued — a per-request
    # sync in the generate setup; set the decode position statically.
    step = jax.jit(bundle.decode_step)
    cache["pos"] = jnp.asarray(lp - 1, jnp.int32)
    logits, cache = step(params, cache, prompt_tokens[:, -1:])
    out = []
    tok = None
    if key is None:
        key = jax.random.PRNGKey(0)
    for t in range(gen_len):
        if tok is None:
            lg = logits[:, -1, : cfg.vocab]
        else:
            logits, cache = step(params, cache, tok)
            lg = logits[:, -1, : cfg.vocab]
        if temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, lg / temperature)[:, None]
        else:
            tok = jnp.argmax(lg, axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_ALIASES), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None,
                    help="personalized checkpoint from launch/train --save")
    ap.add_argument("--client", type=int, default=0,
                    help="which client's personalized model to serve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = build_model(cfg, attn_mode="ref" if args.smoke else "blocked")
    key = jax.random.PRNGKey(args.seed)

    if args.ckpt:
        import numpy as _np
        with _np.load(args.ckpt) as data:
            import json as _json
            meta = _json.loads(data["__metadata__"].tobytes().decode())
            n = int(meta.get("n_clients", 1))
        like_one = jax.eval_shape(bundle.init, key)
        like = {
            "personalized": jax.tree.map(
                lambda l: _np.zeros((n,) + l.shape, l.dtype), like_one),
            "u": _np.zeros((n, 2), _np.float32),
        }
        blob, _ = ckpt.restore(args.ckpt, like)
        params = jax.tree.map(lambda l: jnp.asarray(l[args.client]),
                              blob["personalized"])
        print(f"serving client {args.client}/{n} personalized model from "
              f"{args.ckpt}")
    else:
        params = bundle.init(key)
        print("serving a randomly initialized model (no --ckpt)")

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, dtype=jnp.int32
    )
    frames = None
    if cfg.family == "audio":
        d_enc = cfg.encoder_d_model or cfg.d_model
        frames = jnp.zeros(
            (args.batch, cfg.encoder_frames or 16, d_enc), jnp.float32)

    max_len = args.prompt_len + args.gen + 1
    t0 = time.time()
    toks = generate(
        bundle, params, prompts, gen_len=args.gen, max_len=max_len,
        frames=frames, temperature=args.temperature, key=key,
    )
    dt = time.time() - t0
    print(f"generated {args.gen} tokens × {args.batch} requests in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
