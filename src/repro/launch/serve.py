"""Serving launcher: thin CLI over the serve/ mixture-serving subsystem.

After FedSPD training the product is Eq. (2)'s per-user mixture of S
cluster models. This driver builds a ``ServeConfig`` from flags, loads a
servable artifact (experiments/export.py / ``launch/train --export-
servable``), and answers a request batch off the hot cluster plane in ONE
compiled program — per-user models are never materialized.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke \\
      --artifact runs/servable.npz --client 0 --batch 4 --gen 16

  # heterogeneous batch: every request its own mixture over S clusters
  ... --mixture 0.7,0.3

Legacy surface (DeprecationWarning shims, one release):
  --ckpt/--client   pytree-restore serving of one materialized client
  generate(...)     module-level per-model decode loop
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ARCH_ALIASES
from repro.core.packing import make_pack_spec, pack
from repro.models.registry import build_model
from repro.serve import ClusterPlaneServer, ServeConfig, load_servable
from repro.telemetry import trace_session, write_events


def generate(bundle, params, prompt_tokens, *, gen_len: int, max_len: int,
             frames=None, temperature: float = 0.0, key=None):
    """DEPRECATED: serve through serve.ClusterPlaneServer / ServeConfig.

    Kept for one release as a shim: the materialized ``params`` pytree is
    packed as a single-cluster plane and decoded by the server's
    one-compile step (identical tokens, same re-score-last-prompt-token
    contract). ``max_len`` is derived by the server; the argument is
    accepted and ignored beyond a sanity check."""
    warnings.warn(
        "launch.serve.generate is deprecated; build a serve.ServeConfig "
        "and use serve.ClusterPlaneServer.generate",
        DeprecationWarning, stacklevel=2,
    )
    if frames is not None:
        raise NotImplementedError(
            "audio serving needs a decoder prefill over the prompt tokens "
            "(encdec_prefill_cross only fills the cross-attention cache); "
            "use launch/dryrun.py's serve shapes for audio"
        )
    del max_len  # server derives prompt_len + gen + 1 itself
    spec = make_pack_spec(params)
    plane = pack(params, spec)[None, :]                    # (1, X)
    server = ClusterPlaneServer(spec, plane=plane, bundle=bundle)
    b = prompt_tokens.shape[0]
    u = jnp.ones((b, 1), jnp.float32)
    return server.generate(u, prompt_tokens, gen=gen_len,
                           temperature=temperature, key=key)


def _parse_mixture(text):
    if text is None:
        return None
    return np.asarray([float(t) for t in text.split(",")], np.float32)


def build_config(args) -> ServeConfig:
    """Flags -> resolved ServeConfig (the CLI's only config authority)."""
    return ServeConfig(
        arch=args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len, gen=args.gen,
        temperature=args.temperature, client=args.client,
        mixture=_parse_mixture(args.mixture), codec=args.codec,
        seed=args.seed,
    ).resolve()


def _serve_legacy_ckpt(args, bundle, key):
    """DEPRECATED --ckpt path: restore ONE client's materialized pytree
    from a launch/train --save checkpoint and serve it as a single-
    cluster plane. The manifest (or upconverted legacy blob) must declare
    n_clients — no silent ``.get("n_clients", 1)`` default."""
    warnings.warn(
        "--ckpt serving is deprecated; export a servable artifact "
        "(launch/train --export-servable / experiments.export_run) and "
        "pass --artifact",
        DeprecationWarning, stacklevel=2,
    )
    manifest = ckpt.read_manifest(args.ckpt).need("n_clients")
    n = int(manifest.n_clients)
    like_one = jax.eval_shape(bundle.init, key)
    like = {
        "personalized": jax.tree.map(
            lambda l: np.zeros((n,) + l.shape, l.dtype), like_one),
        "u": np.zeros((n, manifest.n_clusters or 2), np.float32),
    }
    blob, _ = ckpt.restore(args.ckpt, like)
    client = args.client or 0
    params = jax.tree.map(lambda l: jnp.asarray(l[client]),
                          blob["personalized"])
    spec = make_pack_spec(params)
    plane = pack(params, spec)[None, :]
    print(f"serving client {client}/{n} personalized model from {args.ckpt}")
    return ClusterPlaneServer(spec, plane=plane, bundle=bundle), \
        np.ones((args.batch, 1), np.float32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_ALIASES), default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--artifact", default=None,
                    help="servable cluster-plane artifact "
                         "(launch/train --export-servable)")
    ap.add_argument("--client", type=int, default=None,
                    help="serve this trained client's mixture row")
    ap.add_argument("--mixture", default=None,
                    help="explicit mixture weights, e.g. 0.7,0.3 "
                         "(exclusive with --client)")
    ap.add_argument("--codec", choices=("fp32", "int8", "int4"),
                    default="fp32", help="plane shipping format expected "
                                         "in the artifact")
    ap.add_argument("--ckpt", default=None,
                    help="DEPRECATED: personalized checkpoint from "
                         "launch/train --save (use --artifact)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-out", default=None,
                    help="write serve-path telemetry (latency percentiles, "
                         "QPS, plane residency) as a JSONL event log")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the serve batch "
                         "into this directory (Perfetto-loadable)")
    args = ap.parse_args(argv)

    cfg = build_config(args)
    arch_cfg = cfg.arch_config()
    bundle = build_model(arch_cfg, attn_mode="ref" if cfg.smoke else "blocked")
    key = jax.random.PRNGKey(cfg.seed)

    if args.ckpt:
        server, u = _serve_legacy_ckpt(args, bundle, key)
    else:
        spec = make_pack_spec(jax.eval_shape(bundle.init, key))
        if args.artifact:
            art = load_servable(args.artifact, spec)
            art.manifest.check(arch=cfg.arch, codec=cfg.codec)
            server = ClusterPlaneServer.from_artifact(art, spec,
                                                      bundle=bundle)
            u = cfg.request_mixture(server.n_clusters, art.u_table)
            print(f"serving {server.n_clusters}-cluster {art.codec} plane "
                  f"from {args.artifact}")
        else:
            # no artifact: random S=2 plane (smoke / latency probing)
            plane = jnp.stack([
                pack(bundle.init(jax.random.PRNGKey(cfg.seed + s)), spec)
                for s in range(2)
            ])
            server = ClusterPlaneServer(spec, plane=plane, bundle=bundle)
            u = cfg.request_mixture(2)
            print("serving a randomly initialized 2-cluster plane "
                  "(no --artifact)")

    prompts = jax.random.randint(
        key, (cfg.batch, cfg.prompt_len), 0, arch_cfg.vocab, dtype=jnp.int32
    )
    t0 = time.time()
    with trace_session(args.profile_dir):
        toks = server.generate(u, prompts, gen=cfg.gen,
                               temperature=cfg.temperature, key=key)
        toks = jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"generated {cfg.gen} tokens × {cfg.batch} requests in {dt:.2f}s "
          f"({cfg.gen * cfg.batch / dt:.1f} tok/s, "
          f"{server.n_compiles} compile(s), "
          f"{server.n_dispatches} dispatch(es))")
    print(np.asarray(toks))
    if args.telemetry_out:
        snap = server.telemetry_snapshot()
        events = [
            {"event": "serve_meta", "arch": cfg.arch, "codec": snap["codec"],
             "n_clusters": snap["n_clusters"],
             "plane_bytes": snap["plane_bytes"]},
            {"event": "serve_batch", "entry": "generate", "batch": cfg.batch,
             "latency_ms": server.latency.percentile(50) * 1e3},
            {"event": "serve_summary", **snap},
        ]
        write_events(args.telemetry_out, events)
        print(f"telemetry -> {args.telemetry_out}")


if __name__ == "__main__":
    main()
