"""Checkpointing: pytree <-> npz with structural paths.

FL-aware: FedSPD state (cluster centers with (S, N, ...) leading axes,
mixture coefficients, assignments, round counter) is just a pytree, so the
same mechanism checkpoints single-model training and full federations.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(jax.tree_util.keystr((p,))) for p in path)
        out.append((key, leaf))
    return out


def save(path: str, tree: PyTree, metadata: dict | None = None) -> None:
    """Atomic save of a pytree (+ JSON metadata) to ``path`` (.npz)."""
    arrays = {}
    for key, leaf in _paths(tree):
        arrays[key] = np.asarray(leaf)
    meta = json.dumps(metadata or {})
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __metadata__=np.frombuffer(meta.encode(), dtype=np.uint8),
                     **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def restore(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        meta_raw = data["__metadata__"].tobytes().decode() if "__metadata__" in data else "{}"
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pathk, leaf in flat:
            key = _SEP.join(str(jax.tree_util.keystr((p,))) for p in pathk)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs model "
                    f"{np.shape(leaf)}"
                )
            leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), json.loads(meta_raw)


def latest(dirpath: str, prefix: str = "ckpt_") -> str | None:
    if not os.path.isdir(dirpath):
        return None
    cands = [f for f in os.listdir(dirpath) if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    cands.sort(key=lambda f: int(f[len(prefix):-4]))
    return os.path.join(dirpath, cands[-1])
