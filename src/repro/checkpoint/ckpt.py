"""Checkpointing: pytree <-> npz with structural paths + typed manifest.

FL-aware: FedSPD state (cluster centers with (S, N, ...) leading axes,
mixture coefficients, assignments, round counter) is just a pytree, so the
same mechanism checkpoints single-model training and full federations.

The sidecar that used to be a free-form JSON blob (``__metadata__`` bytes
in a uint8 array, read back with ``meta.get(..., 1)`` silent defaults) is
now a typed ``CkptManifest``: what a reader needs to interpret the arrays
— arch, client/cluster cardinality, plane shape, PackSpec digest, wire
codec — as declared fields, with ``need``/``check`` raising errors that
NAME the missing or mismatched field. Legacy blobs still load (upconverted
with a DeprecationWarning) for one release.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"
_MANIFEST_KEY = "__manifest__"
_LEGACY_KEY = "__metadata__"

MANIFEST_VERSION = 2

# Fields a manifest declares (everything else rides in ``extra``).
_FIELDS = ("kind", "arch", "n_clients", "n_clusters", "plane_shape",
           "pack_digest", "codec", "qblock")


@dataclasses.dataclass(frozen=True)
class CkptManifest:
    """Typed checkpoint sidecar. ``None`` means "writer did not declare
    it" — readers that depend on a field call ``need(...)`` and get a
    hard error naming it, instead of a silent default."""

    kind: str = "checkpoint"            # "checkpoint" | "servable" | ...
    arch: Optional[str] = None          # model registry name
    n_clients: Optional[int] = None     # N
    n_clusters: Optional[int] = None    # S
    plane_shape: Optional[tuple] = None  # packed plane dims, e.g. (S, X)
    pack_digest: Optional[str] = None   # PackSpec.digest of the layout
    codec: str = "fp32"                 # wire codec of stored plane
    qblock: Optional[int] = None        # quantization block (quant codecs)
    version: int = MANIFEST_VERSION
    extra: dict = dataclasses.field(default_factory=dict)

    def need(self, *fields: str) -> "CkptManifest":
        """Assert the named fields were declared by the writer; error
        names every missing one (no ``.get(..., default)`` fallbacks)."""
        missing = [f for f in fields if getattr(self, f, None) is None]
        if missing:
            raise KeyError(
                "checkpoint manifest missing required field(s) "
                f"{missing} (kind={self.kind!r}); re-export with a writer "
                "that declares them"
            )
        return self

    def check(self, **expected: Any) -> "CkptManifest":
        """Assert declared fields match ``expected`` exactly; mismatches
        are reported per-field with both values."""
        bad = []
        for f, want in expected.items():
            got = getattr(self, f)
            if isinstance(got, tuple) or isinstance(want, (tuple, list)):
                got, want = tuple(got or ()), tuple(want or ())
            if got != want:
                bad.append(f"{f}: manifest {got!r} != expected {want!r}")
        if bad:
            raise ValueError(
                "checkpoint manifest mismatch — " + "; ".join(bad)
            )
        return self

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        if d["plane_shape"] is not None:
            d["plane_shape"] = list(d["plane_shape"])
        return json.dumps(d)

    @classmethod
    def from_json(cls, raw: str) -> "CkptManifest":
        d = json.loads(raw)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = {k: d.pop(k) for k in list(d) if k not in known}
        if d.get("plane_shape") is not None:
            d["plane_shape"] = tuple(d["plane_shape"])
        if unknown:
            d.setdefault("extra", {}).update(unknown)
        return cls(**d)

    @classmethod
    def from_legacy(cls, meta: dict) -> "CkptManifest":
        """Upconvert a v1 free-form metadata dict: recognized keys become
        declared fields, the rest lands in ``extra`` verbatim."""
        meta = dict(meta)
        kw: dict[str, Any] = {"version": 1}
        for f in _FIELDS:
            if f in meta:
                kw[f] = meta.pop(f)
        if kw.get("plane_shape") is not None:
            kw["plane_shape"] = tuple(kw["plane_shape"])
        kw["extra"] = meta
        return cls(**kw)


def _paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(jax.tree_util.keystr((p,))) for p in path)
        out.append((key, leaf))
    return out


def save(path: str, tree: PyTree, manifest: CkptManifest | None = None,
         metadata: dict | None = None) -> None:
    """Atomic save of a pytree (+ manifest) to ``path`` (.npz).

    ``metadata=`` (the v1 loose-dict sidecar) still works but warns; the
    dict is upconverted through ``CkptManifest.from_legacy`` so readers
    see one format either way.
    """
    if metadata is not None:
        warnings.warn(
            "ckpt.save(metadata=...) is deprecated; pass "
            "manifest=CkptManifest(...) instead",
            DeprecationWarning, stacklevel=2,
        )
        if manifest is not None:
            raise ValueError("pass manifest= or metadata=, not both")
        manifest = dataclasses.replace(
            CkptManifest.from_legacy(metadata), version=MANIFEST_VERSION)
    manifest = manifest or CkptManifest()
    arrays = {}
    for key, leaf in _paths(tree):
        arrays[key] = np.asarray(leaf)
    raw = manifest.to_json().encode()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{_MANIFEST_KEY: np.frombuffer(raw, dtype=np.uint8)},
                     **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _load_manifest(data) -> CkptManifest:
    if _MANIFEST_KEY in data:
        return CkptManifest.from_json(
            data[_MANIFEST_KEY].tobytes().decode())
    if _LEGACY_KEY in data:
        warnings.warn(
            "loading legacy __metadata__ JSON-blob checkpoint; re-save "
            "with the CkptManifest writer (support lasts one release)",
            DeprecationWarning, stacklevel=3,
        )
        return CkptManifest.from_legacy(
            json.loads(data[_LEGACY_KEY].tobytes().decode()))
    return CkptManifest(version=1)


def read_manifest(path: str) -> CkptManifest:
    """Peek at a checkpoint's manifest without loading the arrays."""
    with np.load(path) as data:
        return _load_manifest(data)


def restore(path: str, like: PyTree) -> tuple[PyTree, CkptManifest]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        manifest = _load_manifest(data)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pathk, leaf in flat:
            key = _SEP.join(str(jax.tree_util.keystr((p,))) for p in pathk)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs model "
                    f"{np.shape(leaf)}"
                )
            leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def latest(dirpath: str, prefix: str = "ckpt_") -> str | None:
    if not os.path.isdir(dirpath):
        return None
    cands = [f for f in os.listdir(dirpath) if f.startswith(prefix) and f.endswith(".npz")]
    if not cands:
        return None
    cands.sort(key=lambda f: int(f[len(prefix):-4]))
    return os.path.join(dirpath, cands[-1])
