from repro.checkpoint.ckpt import latest, restore, save  # noqa: F401
