from repro.checkpoint.ckpt import (  # noqa: F401
    CkptManifest,
    latest,
    read_manifest,
    restore,
    save,
)
