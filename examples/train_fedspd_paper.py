"""End-to-end driver at the paper's own experimental scale.

    PYTHONPATH=src python examples/train_fedspd_paper.py [--rounds 150]
    PYTHONPATH=src python examples/train_fedspd_paper.py --seeds 0 1 2

Reproduces the paper's protocol end to end: N=20 clients on a sparse ER
graph (paper B.1: ER p=0.06..0.2), mixture of S=2 distributions with
per-client fractions U[0.1, 0.9], a few hundred FedSPD rounds, the final
personalization phase, and a comparison against DFL baselines — the
Tables 2-3 experiment as one runnable script.  With more than one seed the
registry's batched driver vmaps the round step over the seed axis, so the
whole sweep shares a single jit compilation per method.
"""
import argparse
import time

import numpy as np

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import RunConfig, run_method_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--methods", nargs="+", default=[
        "fedspd", "dfl_fedem", "dfl_ifca", "dfl_fedavg", "local",
    ])
    ap.add_argument("--seeds", nargs="+", type=int, default=[0],
                    help="algorithm seeds; >1 runs vmap-batched")
    ap.add_argument("--gossip-backend", default=None,
                    choices=[None, "reference", "pallas"],
                    help="FedSPD mixing execution path")
    args = ap.parse_args(argv)

    exp = PaperExpConfig(
        n_clients=args.clients, rounds=args.rounds, tau=5, batch=32,
        n_per_client=256, model="mlp", dim=32, n_classes=6, avg_degree=5.0,
    )
    data = make_mixture_classification(
        n_clients=exp.n_clients, n_clusters=2, n_per_client=exp.n_per_client,
        dim=exp.dim, n_classes=exp.n_classes, seed=args.seeds[0], noise=0.25,
    )
    options = (
        {"gossip_backend": args.gossip_backend} if args.gossip_backend else {}
    )
    print(f"clients={exp.n_clients} rounds={exp.rounds} "
          f"points/client={exp.n_per_client} seeds={args.seeds}")
    print(f"{'method':14s} {'acc':>7s} {'acc_sd':>7s} {'std':>7s} "
          f"{'comm MB':>9s} {'wall s':>7s}")
    for method in args.methods:
        t0 = time.time()
        rs = run_method_batch(
            method, data, exp, seeds=args.seeds,
            cfg=RunConfig(
                eval_every=25,
                options=options if method.startswith("fedspd") else {},
            ),
        )
        accs = np.array([r.mean_acc for r in rs])
        print(f"{method:14s} {accs.mean():7.3f} {accs.std():7.3f} "
              f"{np.mean([r.std_acc for r in rs]):7.3f} "
              f"{np.mean([r.comm_bytes for r in rs]) / 1e6:9.1f} "
              f"{time.time() - t0:7.1f}")


if __name__ == "__main__":
    main()
