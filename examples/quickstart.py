"""Quickstart: FedSPD in ~40 lines on a synthetic mixture task.

    PYTHONPATH=src python examples/quickstart.py

8 clients on a sparse ER graph, each holding an unknown mixture of two data
distributions (rotated vs unrotated prototypes — the paper's rotated-MNIST
analogue). FedSPD learns one model per cluster by gossiping cluster centers
with matching neighbors, then personalizes per client (Eq. 2 + local
epochs).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import per_client_eval
from repro.core import (
    FedSPDConfig, GossipSpec, final_phase, make_round_step, seeded_init,
)
from repro.data.synthetic import make_mixture_classification
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier

N_CLIENTS, N_CLUSTERS = 8, 2

data = make_mixture_classification(
    n_clients=N_CLIENTS, n_clusters=N_CLUSTERS, n_per_client=96, dim=16,
    n_classes=4, noise=0.25, seed=0,
)
key = jax.random.PRNGKey(0)
_, apply_fn, loss_fn, per_example_loss, acc_fn = make_classifier(
    "mlp", key, data.x.shape[-1], data.n_classes)


def model_init(k):
    params, *_ = make_classifier("mlp", k, data.x.shape[-1], data.n_classes)
    return params


cfg = FedSPDConfig(n_clients=N_CLIENTS, n_clusters=N_CLUSTERS, tau=5,
                   batch=16, lr0=0.05, tau_final=10)
graph = make_graph("er", N_CLIENTS, avg_degree=4, seed=0)
gossip = GossipSpec.from_graph(graph)

train = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
test = {"inputs": jnp.asarray(data.x_test), "targets": jnp.asarray(data.y_test)}

state = seeded_init(key, model_init, cfg, loss_fn, train)
round_step = jax.jit(make_round_step(loss_fn, per_example_loss, gossip, cfg))

for r in range(50):
    state, metrics = round_step(state, train)
    if r % 10 == 0:
        print(f"round {r:3d}  consensus={np.asarray(metrics['consensus']).round(4)}"
              f"  comm={float(metrics['comm_bytes'])/1e6:.1f} MB")

personalized = final_phase(state, loss_fn, train, cfg)
acc = per_client_eval(acc_fn, personalized, test)
print(f"\nper-client test accuracy: {np.asarray(acc).round(3)}")
print(f"mean: {float(jnp.mean(acc)):.3f}")
print(f"estimated mixtures u:\n{np.asarray(state.u).round(2)}")
print(f"true mixtures:\n{data.mix_true.round(2)}")
