"""Quickstart: FedSPD through the method registry in ~25 lines.

    PYTHONPATH=src python examples/quickstart.py

8 clients on a sparse ER graph, each holding an unknown mixture of two data
distributions (rotated vs unrotated prototypes — the paper's rotated-MNIST
analogue).  ``run_method`` resolves any of the 13 registered algorithms
(``repro.experiments.METHODS``) through one shared driver: FedSPD learns one
model per cluster by gossiping cluster centers with matching neighbors, then
personalizes per client (Eq. 2 + local epochs).  Execution knobs live in
one ``RunConfig``: swap the method id, pass
``RunConfig(gossip_backend="pallas")`` to stream the mixing through the
Pallas kernel, or — as below — ``scan_rounds=True`` to roll all 50 rounds
into ONE compiled lax.scan program (one dispatch total).
"""
import numpy as np

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import METHODS, RunConfig, run_method

N_CLIENTS, N_CLUSTERS = 8, 2

exp = PaperExpConfig(
    n_clients=N_CLIENTS, n_clusters=N_CLUSTERS, rounds=50, tau=5, batch=16,
    lr0=0.05, tau_final=10, n_per_client=96, model="mlp", dim=16, n_classes=4,
    avg_degree=4.0,
)
data = make_mixture_classification(
    n_clients=N_CLIENTS, n_clusters=N_CLUSTERS, n_per_client=96, dim=16,
    n_classes=4, noise=0.25, seed=0,
)

print(f"registered methods: {', '.join(METHODS)}\n")
result = run_method("fedspd", data, exp, seed=0,
                    cfg=RunConfig(eval_every=10, scan_rounds=True))

for r, acc in result.curve:
    print(f"round {r:3d}  mean train acc {acc:.3f}")
print(f"\nper-client test accuracy: {result.acc_per_client.round(3)}")
print(f"mean: {result.mean_acc:.3f} (std across clients {result.std_acc:.3f})")
print(f"communication: {result.comm_bytes / 1e6:.1f} MB logical "
      f"({result.wire_bytes / 1e6:.1f} MB on the wire; add "
      f"comm=CommConfig(codec='int8') to compress)")
print(f"estimated mixtures u:\n{np.asarray(result.extras['u']).round(2)}")
print(f"true mixtures:\n{data.mix_true.round(2)}")
