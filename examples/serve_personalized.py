"""Train a small FedSPD federation of LM clients, export the consensus
cluster plane as a servable artifact, then serve personalized mixtures —
one trained client's row AND a heterogeneous request batch — off the hot
plane through the serve/ subsystem.

    PYTHONPATH=src python examples/serve_personalized.py --arch mamba2-370m

Uses the reduced (smoke) variant of the chosen assigned architecture so the
whole loop runs on CPU; the full-scale serving program is proven by
launch/dryrun.py (decode_32k / long_500k lower serve_step).
"""
import argparse

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--codec", default="int4",
                    choices=["fp32", "int8", "int4"],
                    help="plane shipping format for the servable export")
    args = ap.parse_args(argv)

    artifact = "/tmp/fedspd_servable.npz"
    print("=== phase 1: FedSPD training across", args.clients, "clients ===")
    train_mod.main([
        "--arch", args.arch, "--smoke", "--rounds", str(args.rounds),
        "--clients", str(args.clients), "--batch", "2", "--seq", "48",
        "--eval-every", "4", "--export-servable", artifact,
        "--export-codec", args.codec,
    ])
    print("\n=== phase 2: serve client 0's trained mixture ===")
    serve_mod.main([
        "--arch", args.arch, "--smoke", "--artifact", artifact,
        "--codec", args.codec, "--client", "0",
        "--batch", "4", "--prompt-len", "16", "--gen", "8",
    ])
    print("\n=== phase 3: heterogeneous batch (explicit mixture) ===")
    serve_mod.main([
        "--arch", args.arch, "--smoke", "--artifact", artifact,
        "--codec", args.codec, "--mixture", "0.7,0.3",
        "--batch", "4", "--prompt-len", "16", "--gen", "8",
    ])


if __name__ == "__main__":
    main()
