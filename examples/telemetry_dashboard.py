"""Telemetry dashboard: render run JSONL event logs as terminal plots.

The observability companion to examples/connectivity_sweep.py: where the
sweep prints the final accuracy frontier, this renders HOW each run got
there — per-round sparklines of the traced metric streams (cluster-weight
entropy and drift, per-cluster consensus residual, effective degree and
spectral gap of the round's surviving topology, wire bytes) straight from
the structured JSONL event log, no plotting dependencies.

Two modes:

    # render existing logs (launch/train --telemetry-out, or the files
    # this script writes itself)
    PYTHONPATH=src python examples/telemetry_dashboard.py runs/*.jsonl

    # no args: run a small low-connectivity sweep with telemetry on,
    # write one JSONL per cell, and render them
    PYTHONPATH=src python examples/telemetry_dashboard.py
"""
import sys
import tempfile

import numpy as np

from repro.telemetry import read_events, streams_from_events, summary_table

BARS = "▁▂▃▄▅▆▇█"


def spark(xs) -> str:
    """One line of unicode bars for a per-round scalar stream."""
    xs = np.asarray(xs, np.float64)
    ok = np.isfinite(xs)
    if not ok.any():
        return "·" * len(xs)
    lo, hi = xs[ok].min(), xs[ok].max()
    span = (hi - lo) or 1.0
    out = []
    for v in xs:
        if not np.isfinite(v):
            out.append("·")
        else:
            out.append(BARS[int((v - lo) / span * (len(BARS) - 1))])
    return "".join(out)


def _scalarize(stream) -> np.ndarray:
    """Per-round scalar view: vector streams (consensus, histogram)
    render as their per-round sum."""
    arr = np.asarray(stream, np.float64)
    return arr if arr.ndim == 1 else arr.reshape(arr.shape[0], -1).sum(-1)


def render(path: str) -> None:
    events = read_events(path)
    streams = streams_from_events(events)
    print(summary_table(events), end="")
    if not streams:
        return
    width = max(len(n) for n in streams)
    print("per-round sparklines (first -> last round):")
    for name in sorted(streams):
        xs = _scalarize(streams[name])
        lo = np.nanmin(xs) if np.isfinite(xs).any() else float("nan")
        hi = np.nanmax(xs) if np.isfinite(xs).any() else float("nan")
        print(f"  {name:>{width}s}  {spark(xs)}  "
              f"[{lo:.4g} .. {hi:.4g}]")
    print()


def demo_sweep(out_dir: str) -> list[str]:
    """A small connectivity sweep with the telemetry plane on: one
    scan-rolled run per degree, one JSONL per cell."""
    from repro.configs.paper_cnn import PaperExpConfig
    from repro.data.synthetic import make_mixture_classification
    from repro.experiments import RunConfig, TelemetryConfig, run_method
    from repro.graphs.topology import make_graph
    from repro.telemetry import write_run_jsonl

    exp = PaperExpConfig(n_clients=12, rounds=30, tau=2, batch=16,
                         n_per_client=64, model="mlp", dim=16, n_classes=4)
    data = make_mixture_classification(
        n_clients=exp.n_clients, n_clusters=2,
        n_per_client=exp.n_per_client, dim=exp.dim,
        n_classes=exp.n_classes, seed=1, noise=0.25,
    )
    paths = []
    for deg in (2.5, 4.0, 6.0):
        g = make_graph("er", exp.n_clients, deg, seed=2)
        r = run_method("fedspd", data, exp, graph=g, seed=0,
                       cfg=RunConfig(eval_every=5, param_plane=True,
                                     scan_rounds=True,
                                     telemetry=TelemetryConfig()))
        path = f"{out_dir}/fedspd_er_deg{deg}.jsonl"
        write_run_jsonl(path, r, meta={"n_clients": exp.n_clients,
                                       "n_clusters": 2, "seed": 0,
                                       "graph": f"er deg={deg}"})
        paths.append(path)
        print(f"deg {deg:4.1f}: acc {r.mean_acc:.3f}  "
              f"({r.extras['n_compiles']} compile, "
              f"{r.extras['n_dispatches']} dispatch) -> {path}")
    print()
    return paths


if __name__ == "__main__":
    paths = sys.argv[1:]
    if not paths:
        tmp = tempfile.mkdtemp(prefix="fedspd_telemetry_")
        print("no JSONL paths given — running the demo sweep "
              f"(telemetry plane on, logs under {tmp})\n")
        paths = demo_sweep(tmp)
    for p in paths:
        render(p)
