"""The paper's headline claim (Figure 4): FedSPD keeps its accuracy in
LOW-connectivity networks where other DFL methods degrade.

    PYTHONPATH=src python examples/connectivity_sweep.py
"""
from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import run_method
from repro.graphs.topology import make_graph

exp = PaperExpConfig(n_clients=12, rounds=60, tau=5, batch=16,
                     n_per_client=128, model="mlp", dim=16, n_classes=4)
data = make_mixture_classification(
    n_clients=exp.n_clients, n_clusters=2, n_per_client=exp.n_per_client,
    dim=exp.dim, n_classes=exp.n_classes, seed=1, noise=0.25,
)

print(f"{'topology':9s} {'deg':>5s} {'fedspd':>8s} {'dfl_fedem':>10s} "
      f"{'dfl_fedavg':>11s}")
for kind in ("er", "ba", "rgg"):
    for deg in (2.5, 4.0, 6.0):
        g = make_graph(kind, exp.n_clients, deg, seed=2)
        row = []
        for m in ("fedspd", "dfl_fedem", "dfl_fedavg"):
            r = run_method(m, data, exp, graph=g, seed=0, eval_every=10**9)
            row.append(r.mean_acc)
        print(f"{kind:9s} {g.avg_degree:5.1f} {row[0]:8.3f} {row[1]:10.3f} "
              f"{row[2]:11.3f}")
