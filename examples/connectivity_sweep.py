"""The paper's headline claim (Figure 4): FedSPD keeps its accuracy in
LOW-connectivity networks where other DFL methods degrade — extended with
the BANDWIDTH axis the compressed-communication subsystem opens (the same
sweep per wire codec, so each (topology, degree) cell reads as an
accuracy-vs-wire-bytes frontier) and the DYNAMIC-TOPOLOGY axis the
scenario engine opens (Appendix B.2.4: per-round rewired graphs, plus
Bernoulli link dropout — each scheduled round's adjacency is a traced
input, so the whole dynamic sweep still compiles once per cell).

All runs use the packed parameter plane (the compressing codecs operate on
flat (N, X) slices; ``run_method`` enables it for them automatically, and
``RunConfig(param_plane=True)`` keeps the fp32 baseline on the identical
engine). The dynamic sweep additionally sets ``scan_rounds=True``: the
whole 60-round experiment is one lax.scan-rolled compiled program.

    PYTHONPATH=src python examples/connectivity_sweep.py
"""
from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification
from repro.experiments import CommConfig, RunConfig, Scenario, run_method
from repro.graphs.topology import make_graph, rewire_schedule

exp = PaperExpConfig(n_clients=12, rounds=60, tau=5, batch=16,
                     n_per_client=128, model="mlp", dim=16, n_classes=4)
data = make_mixture_classification(
    n_clients=exp.n_clients, n_clusters=2, n_per_client=exp.n_per_client,
    dim=exp.dim, n_classes=exp.n_classes, seed=1, noise=0.25,
)

CODECS = {
    "fp32": CommConfig(codec="fp32"),
    "int8+ef": CommConfig(codec="int8", error_feedback=True),
    "topk+ef": CommConfig(codec="topk", error_feedback=True),
}

print("connectivity sweep (paper Fig. 4) x bandwidth axis "
      "(accuracy @ wire MB)\n")
header = f"{'topology':9s} {'deg':>5s} {'codec':>8s}"
for m in ("fedspd", "dfl_fedem", "dfl_fedavg"):
    header += f" {m + ' acc@MB':>21s}"
print(header)
for kind in ("er", "ba", "rgg"):
    for deg in (2.5, 4.0, 6.0):
        g = make_graph(kind, exp.n_clients, deg, seed=2)
        for name, comm in CODECS.items():
            row = f"{kind:9s} {g.avg_degree:5.1f} {name:>8s}"
            for m in ("fedspd", "dfl_fedem", "dfl_fedavg"):
                r = run_method(m, data, exp, graph=g, seed=0,
                               cfg=RunConfig(eval_every=10**9,
                                             param_plane=True, comm=comm))
                row += f" {r.mean_acc:12.3f}@{r.wire_bytes / 1e6:7.1f}"
            print(row)
        print()

# dynamic-topology axis (scenario engine): the same low-connectivity sweep
# under per-round rewiring and 20% link dropout — FedSPD's accuracy under
# graphs that never sit still, at the wire bytes the surviving links cost
print("dynamic topologies (rewired every round, 20% link dropout) — "
      "fedspd acc@MB")
for kind in ("er", "ba", "rgg"):
    for deg in (2.5, 4.0):
        sched = rewire_schedule(kind, exp.n_clients, deg, rounds=exp.rounds,
                                p_rewire=0.3, seed=2)
        sc = Scenario(graph_schedule=sched, dropout=0.2, seed=2)
        r = run_method("fedspd", data, exp, seed=0,
                       cfg=RunConfig(eval_every=10**9, param_plane=True,
                                     scenario=sc, scan_rounds=True))
        print(f"{kind:9s} {deg:5.1f} {'dynamic':>8s} "
              f"{r.mean_acc:12.3f}@{r.wire_bytes / 1e6:7.1f}  "
              f"(compiles: {r.extras['n_compiles']})")
