"""Paper Tables 2 & 3: FedSPD vs CFL/DFL baselines — mean test accuracy.

Every method resolves through the experiment registry, and repeated trials
run through the multi-seed batched driver with the STACKED-DATA variant:
each seed draws its own dataset (the paper's across-dataset repeated-trials
protocol, restored from the pre-registry version), and all per-seed runs
still share ONE jit compile — the (k, N, M, ...) data stack is vmapped over
the seed axis alongside the states (the ROADMAP stacked-data item, closed).

Also produces the Figure 3 analogue (per-client accuracy spread) since the
per-client vectors come for free from the same runs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments import RunConfig, run_method_batch

DFL = ["fedspd", "dfl_fedem", "dfl_ifca", "dfl_fedavg", "dfl_fedsoft",
       "dfl_pfedme", "local"]
CFL = ["cfl_fedem", "cfl_ifca", "cfl_fedavg", "cfl_fedsoft", "cfl_pfedme"]


def run(fast: bool = True, seeds=(0,)) -> dict:
    exp = exp_config(fast)
    # per-seed datasets: k seeds × k datasets in one compile
    data = [mixture_data(exp, seed=3 + int(s)) for s in seeds]
    rows = []
    for method in DFL + CFL:
        results = run_method_batch(method, data, exp, seeds=seeds,
                                   cfg=RunConfig(eval_every=10**9))
        rows.append({
            "method": method,
            "acc": float(np.mean([r.mean_acc for r in results])),
            "acc_std_across_clients": float(
                np.mean([r.std_acc for r in results])),
            "comm_GB": float(np.mean([r.comm_bytes for r in results])) / 1e9,
            "n_compiles": int(results[0].extras.get("n_compiles", 1)),
        })
    out = {"table": rows, "exp": exp.__dict__, "seeds": list(seeds)}
    print(fmt_table(rows, ["method", "acc", "acc_std_across_clients",
                           "comm_GB"],
                    "Tables 2-3 analogue: test accuracy (mixture task)"))
    save_result("table23_baselines", out)
    return out


if __name__ == "__main__":
    run()
