"""Paper Tables 2 & 3: FedSPD vs CFL/DFL baselines — mean test accuracy.

Also produces the Figure 3 analogue (per-client accuracy spread) since the
per-client vectors come for free from the same runs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments.runner import run_method

DFL = ["fedspd", "dfl_fedem", "dfl_ifca", "dfl_fedavg", "dfl_fedsoft",
       "dfl_pfedme", "local"]
CFL = ["cfl_fedem", "cfl_ifca", "cfl_fedavg", "cfl_fedsoft", "cfl_pfedme"]


def run(fast: bool = True, seeds=(0,)) -> dict:
    exp = exp_config(fast)
    rows = []
    for method in DFL + CFL:
        accs, stds, comms = [], [], []
        for seed in seeds:
            data = mixture_data(exp, seed=3 + seed)
            r = run_method(method, data, exp, seed=seed, eval_every=10**9)
            accs.append(r.mean_acc)
            stds.append(r.std_acc)
            comms.append(r.comm_bytes)
        rows.append({
            "method": method,
            "acc": float(np.mean(accs)),
            "acc_std_across_clients": float(np.mean(stds)),
            "comm_GB": float(np.mean(comms)) / 1e9,
        })
    out = {"table": rows, "exp": exp.__dict__}
    print(fmt_table(rows, ["method", "acc", "acc_std_across_clients",
                           "comm_GB"],
                    "Tables 2-3 analogue: test accuracy (mixture task)"))
    save_result("table23_baselines", out)
    return out


if __name__ == "__main__":
    run()
