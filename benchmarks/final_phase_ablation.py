"""Paper B.2.2 (Figure 6): contribution of the final personalization phase —
accuracy right after Eq. (2) aggregation vs after τ_final local epochs.

One FedSPD state is trained through the registry's method-object API, then
re-personalized under a sweep of ``tau_final`` values (``tau_final=0``
degenerates to the pure Eq. (2) aggregate) without retraining.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments import build_context, get_method


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    m = get_method("fedspd")
    ctx = build_context(data, exp, seed=0)
    key = jax.random.PRNGKey(0)
    k_init, k_run, k_eval = jax.random.split(key, 3)
    state = m.init(ctx, k_init)
    step = jax.jit(m.make_step(ctx))
    for r in range(exp.rounds):
        k_run, k = jax.random.split(k_run)
        state, _ = step(state, ctx.train, k, exp.lr0 * exp.lr_decay ** r)

    rows = []
    for tf in ([0, 2, 5, 10] if fast else [0, 2, 5, 10, 20, 30]):
        ctx_tf = dataclasses.replace(ctx, options={**ctx.options,
                                                   "tau_final": tf})
        acc = float(np.mean(m.evaluate(ctx_tf, state, k_eval, ctx.test)))
        stage = ("post-aggregation (Eq. 2)" if tf == 0
                 else f"final phase {tf} epochs")
        rows.append({"stage": stage, "acc": acc})
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["stage", "acc"], "B.2.2: final phase contribution"))
    save_result("final_phase_ablation", out)
    return out


if __name__ == "__main__":
    run()
