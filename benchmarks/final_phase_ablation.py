"""Paper B.2.2 (Figure 6): contribution of the final personalization phase —
accuracy right after Eq. (2) aggregation vs after τ_final local epochs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.baselines.common import per_client_eval
from repro.core import (
    FedSPDConfig, GossipSpec, final_phase, make_round_step, personalize,
    seeded_init,
)
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    key = jax.random.PRNGKey(0)
    _, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        exp.model, key, data.x.shape[-1], data.n_classes)

    def model_init(k):
        p, *_ = make_classifier(exp.model, k, data.x.shape[-1], data.n_classes)
        return p

    fcfg = FedSPDConfig(n_clients=exp.n_clients, n_clusters=2, tau=exp.tau,
                        batch=exp.batch, lr0=exp.lr0, tau_final=exp.tau_final)
    spec = GossipSpec.from_graph(make_graph(exp.graph_kind, exp.n_clients,
                                            exp.avg_degree, seed=0))
    train = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    test = {"inputs": jnp.asarray(data.x_test), "targets": jnp.asarray(data.y_test)}
    state = seeded_init(key, model_init, fcfg, loss_fn, train)
    step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))
    for _ in range(exp.rounds):
        state, _ = step(state, train)

    rows = []
    post_agg = personalize(state)
    rows.append({"stage": "post-aggregation (Eq. 2)",
                 "acc": float(np.mean(per_client_eval(acc_fn, post_agg, test)))})
    for tf in ([0, 2, 5, 10] if fast else [0, 2, 5, 10, 20, 30]):
        import dataclasses
        f2 = dataclasses.replace(fcfg, tau_final=tf)
        pers = post_agg if tf == 0 else final_phase(state, loss_fn, train, f2)
        rows.append({"stage": f"final phase {tf} epochs",
                     "acc": float(np.mean(per_client_eval(acc_fn, pers, test)))})
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["stage", "acc"], "B.2.2: final phase contribution"))
    save_result("final_phase_ablation", out)
    return out


if __name__ == "__main__":
    run()
