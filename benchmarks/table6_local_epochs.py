"""Paper Table 6 / Figure 5 (B.2.1): FedSPD accuracy vs number of local
epochs τ."""
from __future__ import annotations

import dataclasses

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments import RunConfig, run_method


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    taus = [1, 3, 5] if fast else [1, 5, 10]
    rows = []
    for tau in taus:
        e = dataclasses.replace(exp, tau=tau)
        r = run_method("fedspd", data, e, seed=0,
                       cfg=RunConfig(eval_every=10**9))
        rows.append({"tau": tau, "acc": round(r.mean_acc, 4)})
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["tau", "acc"],
                    "Table 6 analogue: FedSPD vs local epochs"))
    save_result("table6_local_epochs", out)
    return out


if __name__ == "__main__":
    run()
