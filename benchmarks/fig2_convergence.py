"""Paper Figure 2: training accuracy vs round for the DFL methods —
FedSPD converges fastest."""
from __future__ import annotations

from benchmarks.common import exp_config, mixture_data, save_result
from repro.experiments import RunConfig, run_method

METHODS = ["fedspd", "dfl_fedem", "dfl_ifca", "dfl_fedavg", "dfl_fedsoft"]


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    curves = {}
    for m in METHODS:
        r = run_method(m, data, exp, seed=0,
                       cfg=RunConfig(eval_every=max(2, exp.rounds // 10)))
        curves[m] = r.curve
        print(f"{m:14s}: " + " ".join(f"{a:.2f}" for _, a in r.curve))
    out = {"curves": curves, "exp": exp.__dict__}
    save_result("fig2_convergence", out)
    return out


if __name__ == "__main__":
    run()
