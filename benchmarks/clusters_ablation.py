"""Paper B.2.3 (Figure 7): FedSPD test accuracy vs number of clusters S.
Data is built with 4 true distributions (mode='both': rotation × label
split); S is swept over {2, 3, 4, 6}."""
from __future__ import annotations

import dataclasses

from benchmarks.common import exp_config, fmt_table, save_result
from repro.data.synthetic import make_mixture_classification
from repro.experiments import RunConfig, run_method


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = make_mixture_classification(
        n_clients=exp.n_clients, n_clusters=4, n_per_client=exp.n_per_client,
        dim=exp.dim, n_classes=exp.n_classes, seed=4, noise=0.25, mode="both",
    )
    rows = []
    for s in ([2, 4] if fast else [2, 3, 4, 6]):
        d = dataclasses.replace(data, n_clusters=s)
        r = run_method("fedspd", d, exp, seed=0,
                       cfg=RunConfig(eval_every=10**9))
        rows.append({"S": s, "acc": round(r.mean_acc, 4),
                     "comm_GB": round(r.comm_bytes / 1e9, 3)})
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["S", "acc", "comm_GB"],
                    "B.2.3: accuracy vs number of clusters (4 true)"))
    save_result("clusters_ablation", out)
    return out


if __name__ == "__main__":
    run()
