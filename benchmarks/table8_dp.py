"""Paper Table 8 (B.2.6): FedSPD + differential privacy (Wei et al. 2020).
Clipping C=1, δ=0.01 → noise multiplier c = sqrt(2 ln(1.25/δ))/ε for
ε ∈ {10, 50, 100}. Reports accuracy post-aggregation AND after the (local,
noise-free) final phase."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.baselines.common import per_client_eval
from repro.core import (
    FedSPDConfig, GossipSpec, final_phase, make_round_step, personalize,
    seeded_init,
)
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    key = jax.random.PRNGKey(0)
    _, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        exp.model, key, data.x.shape[-1], data.n_classes)

    def model_init(k):
        p, *_ = make_classifier(exp.model, k, data.x.shape[-1], data.n_classes)
        return p

    train = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    test = {"inputs": jnp.asarray(data.x_test), "targets": jnp.asarray(data.y_test)}
    delta = 0.01
    rows = []
    eps_list = [None, 100, 10] if fast else [None, 100, 50, 10]
    for eps in eps_list:
        if eps is None:
            clip, noise = 0.0, 0.0
        else:
            clip = 1.0
            noise = math.sqrt(2 * math.log(1.25 / delta)) / eps
        fcfg = FedSPDConfig(
            n_clients=exp.n_clients, n_clusters=2, tau=exp.tau,
            batch=exp.batch, lr0=exp.lr0, tau_final=exp.tau_final,
            dp_clip=clip, dp_noise_multiplier=noise,
        )
        spec = GossipSpec.from_graph(make_graph(exp.graph_kind, exp.n_clients,
                                                exp.avg_degree, seed=0))
        state = seeded_init(key, model_init, fcfg, loss_fn, train)
        step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))
        for _ in range(exp.rounds):
            state, _ = step(state, train)
        agg = personalize(state)
        pers = final_phase(state, loss_fn, train, fcfg)
        rows.append({
            "epsilon": "no-DP" if eps is None else eps,
            "post_agg": float(np.mean(per_client_eval(acc_fn, agg, test))),
            "after_final": float(np.mean(per_client_eval(acc_fn, pers, test))),
        })
        print(rows[-1])
    out = {"rows": rows, "delta": delta}
    print(fmt_table(rows, ["epsilon", "post_agg", "after_final"],
                    "Table 8 analogue: FedSPD + DP"))
    save_result("table8_dp", out)
    return out


if __name__ == "__main__":
    run()
