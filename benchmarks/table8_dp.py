"""Paper Table 8 (B.2.6): FedSPD + differential privacy (Wei et al. 2020).
Clipping C=1, δ=0.01 → noise multiplier c = sqrt(2 ln(1.25/δ))/ε for
ε ∈ {10, 50, 100}. Reports accuracy post-aggregation AND after the (local,
noise-free) final phase.

Drives the registry's method-object API directly: one trained FedSPD state
per ε, evaluated twice (``tau_final=0`` → pure Eq. (2) aggregation; the
full final phase) without retraining.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments import build_context, get_method


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    m = get_method("fedspd")
    delta = 0.01
    rows = []
    eps_list = [None, 100, 10] if fast else [None, 100, 50, 10]
    for eps in eps_list:
        if eps is None:
            clip, noise = 0.0, 0.0
        else:
            clip = 1.0
            noise = math.sqrt(2 * math.log(1.25 / delta)) / eps
        ctx = build_context(data, exp, seed=0, options={
            "dp_clip": clip, "dp_noise_multiplier": noise,
        })
        key = jax.random.PRNGKey(0)
        k_init, k_run, k_eval = jax.random.split(key, 3)
        state = m.init(ctx, k_init)
        step = jax.jit(m.make_step(ctx))
        for r in range(exp.rounds):
            k_run, k = jax.random.split(k_run)
            state, _ = step(state, ctx.train, k, exp.lr0 * exp.lr_decay ** r)
        ctx_agg = dataclasses.replace(
            ctx, options={**ctx.options, "tau_final": 0})
        rows.append({
            "epsilon": "no-DP" if eps is None else eps,
            "post_agg": float(np.mean(
                m.evaluate(ctx_agg, state, k_eval, ctx.test))),
            "after_final": float(np.mean(
                m.evaluate(ctx, state, k_eval, ctx.test))),
        })
        print(rows[-1])
    out = {"rows": rows, "delta": delta}
    print(fmt_table(rows, ["epsilon", "post_agg", "after_final"],
                    "Table 8 analogue: FedSPD + DP"))
    save_result("table8_dp", out)
    return out


if __name__ == "__main__":
    run()
