"""Paper Figure 4 + Tables 4-5: test accuracy vs connectivity level and
topology (ER / BA / RGG). FedSPD should stay flat (consistently high) while
other DFL methods degrade at low connectivity."""
from __future__ import annotations

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments import RunConfig, run_method
from repro.graphs.topology import make_graph


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    degrees = [2.5, 5.0] if fast else [3.0, 5.0, 8.0, 12.0]
    kinds = ["er", "ba", "rgg"]
    methods = ["fedspd", "dfl_fedem", "dfl_fedavg"] if fast else [
        "fedspd", "dfl_fedem", "dfl_ifca", "dfl_fedavg"]
    rows = []
    for kind in kinds:
        for deg in degrees:
            g = make_graph(kind, exp.n_clients, deg, seed=1)
            row = {"topology": kind, "avg_degree": deg,
                   "actual_degree": round(g.avg_degree, 2)}
            for m in methods:
                r = run_method(m, data, exp, graph=g, seed=0,
                               cfg=RunConfig(eval_every=10**9))
                row[m] = round(r.mean_acc, 4)
            rows.append(row)
            print(row)
    out = {"rows": rows, "exp": exp.__dict__}
    print(fmt_table(rows, ["topology", "avg_degree"] + methods,
                    "Fig 4 / Tables 4-5 analogue: accuracy vs connectivity"))
    save_result("fig4_connectivity", out)
    return out


if __name__ == "__main__":
    run()
