"""Shared benchmark harness.

Each benchmark module reproduces one paper table/figure on the synthetic
mixture analogue (see data/synthetic.py docstring for the mapping) and
returns a JSON-serializable dict. ``--fast`` shrinks clients/rounds so the
full suite completes on CPU; ``--full`` approaches the paper's scale.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.paper_cnn import PaperExpConfig
from repro.data.synthetic import make_mixture_classification

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def exp_config(fast: bool, **overrides) -> PaperExpConfig:
    base = dict(
        n_clients=12 if fast else 24,
        n_per_client=96 if fast else 256,
        rounds=60 if fast else 150,
        tau=3 if fast else 5,
        batch=16,
        model="mlp",
        dim=16,
        n_classes=4,
        avg_degree=3.5,  # keep the ER graph genuinely sparse (p ~ 0.3)
        lr0=5e-2,
    )
    base.update(overrides)
    return PaperExpConfig(**base)


def mixture_data(exp: PaperExpConfig, seed: int = 3, noise: float = 0.25,
                 mode: str = "rotate", n_clusters: int = 2):
    return make_mixture_classification(
        n_clients=exp.n_clients, n_clusters=n_clusters,
        n_per_client=exp.n_per_client, dim=exp.dim, n_classes=exp.n_classes,
        seed=seed, noise=noise, mode=mode,
    )


def save_result(name: str, result: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def fmt_table(rows: list[dict], cols: list[str], title: str = "") -> str:
    lines = []
    if title:
        lines.append(title)
    hdr = " | ".join(f"{c:>14s}" for c in cols)
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in rows:
        lines.append(" | ".join(
            f"{r.get(c, ''):>14.4g}" if isinstance(r.get(c), (int, float))
            else f"{str(r.get(c, '')):>14s}"
            for c in cols
        ))
    return "\n".join(lines)
