"""Bench trend gate: compare a fresh BENCH_roundstep.json against the
previous point of the perf trajectory.

CI's bench-smoke lane runs ``perf_roundstep --smoke`` then calls this with
the previous run's ``bench-roundstep`` artifact as the baseline (falling
back to the committed ``BENCH_roundstep.json`` when no artifact exists —
first run, expired retention, forked PRs). Per-lane medians are compared;
any lane whose median round time regresses by more than ``--threshold``
(default 25%) fails the job. A lane present only in the NEW run (a freshly
added benchmark, e.g. ``fedspd/dynamic_graph``) never fails the gate: its
first timing seeds the baseline for subsequent runs. A markdown delta table — per-lane timings,
the packed-vs-pytree speedup matrix, the wire-byte table for the
compressed-communication lanes (fedspd/comm_*), the sparse-training wire
table (fedspd/sparse_*), the telemetry collection
overhead (fedspd/telemetry_overhead), and the personalized
serving throughput table (serve/mixture_qps*) — is appended to
``$GITHUB_STEP_SUMMARY`` when set, and always printed to stdout.

  python -m benchmarks.compare_bench --baseline prev.json --new BENCH_roundstep.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _lane(row: dict) -> str:
    """Stable lane id; derived from the row fields for pre-lane payloads."""
    if "lane" in row:
        return row["lane"]
    if "method" in row:
        return f"{row['method']}/{'packed' if row['packed'] else 'pytree'}"
    rep = "packed" if row["packed"] else "pytree"
    return f"{row['model']}/{row['regime']}/{row['backend']}/{rep}"


def lane_medians(payload: dict) -> dict:
    """lane -> median round ms (falls back to min-of-reps for old files).

    Harvests the top-level ``results`` list AND every nested ``*_lanes``
    list (comm_lanes, sparse_lanes, serve_lanes, telemetry_lanes, and any
    future sibling) — a timing row recorded only in its nested payload
    cannot dodge the trend gate. Rows present in both places agree by
    construction (perf_roundstep appends the same dict to both), so the
    overwrite is a no-op."""
    rows = list(payload.get("results", []))
    for key, val in payload.items():
        if key.endswith("_lanes") and isinstance(val, list):
            rows.extend(val)
    out = {}
    for r in rows:
        if not isinstance(r, dict):
            continue
        ms = r.get("round_ms_median", r.get("round_ms"))
        if ms is not None:
            out[_lane(r)] = ms
    return out


def compare(base: dict, new: dict, threshold: float) -> tuple[list, list]:
    """Returns (rows, regressions). Each row:
    (lane, old_ms, new_ms, ratio_or_None, status)."""
    old_l, new_l = lane_medians(base), lane_medians(new)
    rows, regressions = [], []
    for lane in sorted(set(old_l) | set(new_l)):
        o, n = old_l.get(lane), new_l.get(lane)
        if o is None:
            # a lane missing from the baseline is NOT a failure: the first
            # run that produces it (e.g. fedspd/dynamic_graph) seeds the
            # trend — the uploaded artifact becomes the next run's baseline
            rows.append((lane, None, n, None, "new lane (seeds baseline)"))
            continue
        if n is None:
            rows.append((lane, o, None, None, "removed"))
            continue
        ratio = n / o if o > 0 else float("inf")
        if ratio > 1.0 + threshold:
            status = f"REGRESSION (> +{threshold:.0%})"
            regressions.append(lane)
        elif ratio < 1.0 - threshold:
            status = "improved"
        else:
            status = "ok"
        rows.append((lane, o, n, ratio, status))
    return rows, regressions


def _fmt(v, spec=".2f") -> str:
    return "—" if v is None else format(v, spec)


def markdown_report(base: dict, new: dict, rows: list,
                    regressions: list, threshold: float) -> str:
    lines = [
        "## bench-roundstep trend",
        "",
        f"baseline: jax {base.get('meta', {}).get('jax', '?')} @ "
        f"{base.get('meta', {}).get('unix_time', '?')} · "
        f"new: jax {new.get('meta', {}).get('jax', '?')} · "
        f"gate: median regression > {threshold:.0%} in any lane",
        "",
        "| lane | prev ms | new ms | Δ | status |",
        "|---|---:|---:|---:|---|",
    ]
    for lane, o, n, ratio, status in rows:
        delta = "—" if ratio is None else f"{(ratio - 1) * 100:+.1f}%"
        lines.append(f"| {lane} | {_fmt(o)} | {_fmt(n)} | {delta} "
                     f"| {status} |")
    lines += [
        "",
        "### packed vs pytree (new run)",
        "",
        "| lane | pytree ms | packed ms | speedup |",
        "|---|---:|---:|---:|",
    ]
    for c in new.get("comparisons", []):
        lane = c.get("lane") or "/".join(
            str(c[k]) for k in ("model", "regime", "backend") if k in c
        )
        lines.append(f"| {lane} | {c['pytree_ms']:.2f} | "
                     f"{c['packed_ms']:.2f} | x{c['speedup']} |")
    if new.get("comm_lanes"):
        old_wire = {r.get("lane"): r.get("wire_model_bytes")
                    for r in base.get("comm_lanes", [])}
        lines += [
            "",
            "### wire bytes (comm lanes)",
            "",
            "| lane | prev wire B | wire B | logical B | ratio | Δ |",
            "|---|---:|---:|---:|---:|---:|",
        ]
        for r in new["comm_lanes"]:
            prev = old_wire.get(r["lane"])
            delta = ("—" if prev in (None, 0)
                     else f"{(r['wire_model_bytes'] / prev - 1) * 100:+.1f}%")
            lines.append(
                f"| {r['lane']} | {_fmt(prev, 'd')} "
                f"| {r['wire_model_bytes']} | {r['logical_model_bytes']} "
                f"| x{r['wire_ratio']} | {delta} |"
            )
    if new.get("sparse_lanes"):
        old_wire = {r.get("lane"): r.get("wire_model_bytes")
                    for r in base.get("sparse_lanes", [])}
        lines += [
            "",
            "### sparse training (DisPFL lanes)",
            "",
            "| lane | density | codec | wire B | dense wire B | vs dense "
            "| Δ wire |",
            "|---|---:|---|---:|---:|---:|---:|",
        ]
        for r in new["sparse_lanes"]:
            prev = old_wire.get(r["lane"])
            delta = ("—" if prev in (None, 0)
                     else f"{(r['wire_model_bytes'] / prev - 1) * 100:+.1f}%")
            lines.append(
                f"| {r['lane']} | {r['density']} | {r['codec']} "
                f"| {r['wire_model_bytes']} | {r['dense_wire_model_bytes']} "
                f"| x{r['wire_vs_dense']} | {delta} |"
            )
    if new.get("telemetry_lanes"):
        old_ov = {r.get("lane"): r.get("paired_overhead_vs_off")
                  for r in base.get("telemetry_lanes", [])}
        lines += [
            "",
            "### telemetry collection overhead",
            "",
            "| lane | off ms | on ms | prev overhead | overhead |",
            "|---|---:|---:|---:|---:|",
        ]
        for r in new["telemetry_lanes"]:
            prev = old_ov.get(r["lane"])
            lines.append(
                f"| {r['lane']} | {r['off_round_ms']:.2f} "
                f"| {r['round_ms']:.2f} | "
                f"{'—' if prev is None else f'x{prev}'} "
                f"| x{r['paired_overhead_vs_off']} |"
            )
    if new.get("serve_lanes"):
        old_qps = {r.get("lane"): r.get("qps")
                   for r in base.get("serve_lanes", [])}
        lines += [
            "",
            "### personalized mixture serving (serve lanes)",
            "",
            "| lane | codec | prev users/s | users/s | batch ms | Δ |",
            "|---|---|---:|---:|---:|---:|",
        ]
        for r in new["serve_lanes"]:
            prev = old_qps.get(r["lane"])
            delta = ("—" if prev in (None, 0)
                     else f"{(r['qps'] / prev - 1) * 100:+.1f}%")
            lines.append(
                f"| {r['lane']} | {r['codec']} | {_fmt(prev, '.1f')} "
                f"| {r['qps']:.1f} | {r['round_ms_median']:.2f} | {delta} |"
            )
    lines.append("")
    lines.append("**FAIL**: " + ", ".join(regressions) if regressions
                 else "**gate green** — no lane regressed past threshold")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="previous BENCH_roundstep.json (artifact or "
                         "committed fallback)")
    ap.add_argument("--new", required=True, dest="new_path",
                    help="freshly produced BENCH_roundstep.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed per-lane median regression (0.25 = +25%%)")
    args = ap.parse_args(argv)

    base, new = load(args.baseline), load(args.new_path)
    rows, regressions = compare(base, new, args.threshold)
    report = markdown_report(base, new, rows, regressions, args.threshold)
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(report)
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
