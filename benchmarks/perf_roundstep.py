"""Round-step wall-time benchmark: packed parameter plane vs pytree state.

Times ONE full round (the hot path of every experiment and of the
production train loop) across:

  representation  pytree leaves (S, N, ...)  vs packed (S, N, X) plane
  gossip backend  reference (dense einsum)   vs pallas streaming kernel
  regime          full (paper-faithful)      vs stream (production)
  model           mlp (few dense leaves)     vs conv (multi-leaf CNN)
  method          FedSPD round step          + registry baseline steps
                                               (dfl_fedavg, dfl_fedem)
  wire codec      fp32                       vs int8 / topk compressed
                                               exchange (comm/codecs),
                                               stable fedspd/comm_* lanes
                                               + wire-byte accounting
  topology        static closure adjacency   vs traced per-round rewire
                                               schedule (scenario engine,
                                               lane fedspd/dynamic_graph)
  round engine    per-round dispatch loop    vs the lax.scan-rolled
                                               whole-experiment program
                                               (lane fedspd/scan_rounds:
                                               ONE compile + ONE dispatch,
                                               asserted) and per-round
                                               cohort subsampling at
                                               N=1024 clients (lane
                                               fedspd/cohort_n1024)
  sparsity        dense plane                vs the DisPFL masked round at
                                               density 0.2, plain and
                                               stacked on int8+EF (lanes
                                               fedspd/sparse_d20 and
                                               fedspd/sparse_comm_int8,
                                               scan-rolled, one dispatch
                                               asserted)
  telemetry       bare round step            vs the step with the traced
                                               round-metrics plane spliced
                                               in (lane fedspd/
                                               telemetry_overhead: paired
                                               collection cost, must stay
                                               within noise)
  serving         personalized mixture       predictions/sec off the hot
                                               cluster plane at simulated
                                               1e6-user cardinality (lanes
                                               serve/mixture_qps fp32 +
                                               serve/mixture_qps_int4
                                               bit-packed fused kernel)

All steps are jitted with the state donated (the production loop's
configuration). Every result row carries a stable ``lane`` id; the output
``BENCH_roundstep.json`` at the repo root is one point of the repo's perf
trajectory — CI uploads it as an artifact from the bench-smoke lane and
``benchmarks/compare_bench.py`` gates each commit against the previous
point (>25% median regression in any lane fails the lane).

  PYTHONPATH=src python -m benchmarks.perf_roundstep --smoke   # CI sizes
  PYTHONPATH=src python -m benchmarks.perf_roundstep           # CPU bench
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp

from repro.comm import CommConfig, make_channel
from repro.core.fedspd import FedSPDConfig, init_state, make_round_step
from repro.core.gossip import GossipSpec, make_mix_fn
from repro.core.packing import make_pack_spec, pack, pack_state
from repro.data.synthetic import make_mixture_classification
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_roundstep.json")


def _block(tree):
    for leaf in jax.tree.leaves(tree):
        leaf.block_until_ready()


def _build(model: str, regime: str, backend: str, packed: bool,
           *, n: int, m: int, dim: int, tau: int, seed: int = 0,
           comm=None):
    data = make_mixture_classification(
        n_clients=n, n_clusters=2, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    key = jax.random.PRNGKey(seed)
    _, _, loss_fn, pel_fn, _ = make_classifier(model, key, dim, 4)

    def model_init(k):
        p, *_ = make_classifier(model, k, dim, 4)
        return p

    fcfg = FedSPDConfig(n_clients=n, n_clusters=2, tau=tau, batch=16,
                        regime=regime)
    spec = GossipSpec.from_graph(make_graph("er", n, 4.0, seed=seed))
    state = init_state(key, model_init, fcfg, m)
    pack_spec = make_pack_spec(jax.eval_shape(model_init, key))
    if packed:
        state = pack_state(state, pack_spec)
        channel = make_channel(comm, pack_spec.size)
        if channel is not None and channel.has_ef:
            state = state._replace(ef=channel.init_residual((n,)))
    step = make_round_step(
        loss_fn, pel_fn, spec, fcfg,
        mix_fn=make_mix_fn(spec, backend, plane=packed, comm=comm),
        pack_spec=pack_spec if packed else None,
        model_bytes=pack_spec.model_bytes,
        donate=True,  # the production loop's configuration
        comm=comm,
    )
    if regime == "full":
        payload = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    else:
        payload = {"x": jnp.asarray(data.x[:, :16]),
                   "y": jnp.asarray(data.y[:, :16])}
    return step, state, payload, pack_spec


def bench_pair(model: str, regime: str, backend: str,
               *, n: int, m: int, dim: int, tau: int, reps: int,
               seed: int = 0) -> list[dict]:
    """Time the pytree and packed representations of the SAME config with
    strictly interleaved repetitions (A, B, A, B, ...) so slow host drift —
    large on shared CPU runners — cancels out of the comparison. Each
    representation reports min-of-reps (measurement noise is strictly
    additive); the speedup is additionally computed as the median of the
    per-rep PAIRED ratios, the statistic least sensitive to drift."""
    built = {p: _build(model, regime, backend, p,
                       n=n, m=m, dim=dim, tau=tau, seed=seed)
             for p in (False, True)}
    compile_s, times = {}, {False: [], True: []}
    states = {}
    for p, (step, state, payload, _) in built.items():
        t0 = time.perf_counter()
        state, _aux = step(state, payload)
        _block(state)
        compile_s[p] = time.perf_counter() - t0
        states[p] = state
    for _ in range(reps):
        for p, (step, _, payload, _) in built.items():
            t0 = time.perf_counter()
            states[p], _aux = step(states[p], payload)
            _block(states[p])
            times[p].append(time.perf_counter() - t0)
    paired = statistics.median(
        a / b for a, b in zip(times[False], times[True])
    )
    out = []
    for p in (False, True):
        pack_spec = built[p][3]
        rep = "packed" if p else "pytree"
        out.append({
            "lane": f"{model}/{regime}/{backend}/{rep}",
            "model": model, "regime": regime, "backend": backend,
            "packed": p,
            "n_clients": n, "n_leaves": pack_spec.n_leaves,
            "n_params": pack_spec.size,
            "compile_s": round(compile_s[p], 4),
            "round_ms": round(min(times[p]) * 1e3, 4),
            "round_ms_median": round(statistics.median(times[p]) * 1e3, 4),
            "paired_speedup_vs_pytree": round(paired, 3) if p else 1.0,
        })
    return out


BASELINE_METHODS = ("dfl_fedavg", "dfl_fedem")
COMM_CODECS = ("int8", "topk")


def bench_dynamic_graph(*, n: int, m: int, dim: int, tau: int, reps: int,
                        seed: int = 0) -> dict:
    """The scenario engine's traced-adjacency round step vs the static
    closure-constant step — packed FedSPD, reference backend, strictly
    interleaved like ``bench_pair``. The dynamic step receives a fresh
    (N, N) slice of a rewire schedule every rep (the realistic access
    pattern: one traced matrix per round, ONE compile for the whole
    schedule); the paired overhead proves the traced-weight refactor does
    not tax the hot path. Stable lane id ``fedspd/dynamic_graph`` for the
    compare_bench trend gate (a baseline without the lane seeds it)."""
    from repro.graphs.topology import rewire_schedule

    built = {p: _build("mlp", "full", "reference", True,
                       n=n, m=m, dim=dim, tau=tau, seed=seed)
             for p in ("static", "dynamic")}
    sched = rewire_schedule("er", n, 4.0, rounds=8, p_rewire=0.3, seed=seed)
    adjs = [jnp.asarray(a) for a in sched.adjs]
    compile_s, times, states = {}, {"static": [], "dynamic": []}, {}
    for p, (step, state, payload, _) in built.items():
        t0 = time.perf_counter()
        if p == "dynamic":
            state, _aux = step(state, payload, adjs[0])
        else:
            state, _aux = step(state, payload)
        _block(state)
        compile_s[p] = time.perf_counter() - t0
        states[p] = state
    for rep in range(reps):
        for p, (step, _, payload, _) in built.items():
            t0 = time.perf_counter()
            if p == "dynamic":
                states[p], _aux = step(states[p], payload,
                                       adjs[rep % len(adjs)])
            else:
                states[p], _aux = step(states[p], payload)
            _block(states[p])
            times[p].append(time.perf_counter() - t0)
    paired = statistics.median(
        b / a for a, b in zip(times["static"], times["dynamic"])
    )
    return {
        "lane": "fedspd/dynamic_graph",
        "n_clients": n, "schedule_rounds": len(adjs),
        "compile_s": round(compile_s["dynamic"], 4),
        "round_ms": round(min(times["dynamic"]) * 1e3, 4),
        "round_ms_median": round(
            statistics.median(times["dynamic"]) * 1e3, 4),
        "static_round_ms": round(min(times["static"]) * 1e3, 4),
        "paired_overhead_vs_static": round(paired, 3),
    }


def bench_comm_pair(codec: str, *, n: int, m: int, dim: int, tau: int,
                    reps: int, seed: int = 0) -> dict:
    """Wire-codec overhead on the packed FedSPD round step: fp32 vs the
    compressed exchange (error feedback on — the production setting),
    interleaved like ``bench_pair``. One row per codec with a STABLE lane
    id (``fedspd/comm_<codec>``) so compare_bench.py trend-gates it, plus
    the static wire-byte accounting for the step-summary delta table."""
    comm = CommConfig(codec=codec, error_feedback=True)
    built = {
        False: _build("mlp", "full", "reference", True,
                      n=n, m=m, dim=dim, tau=tau, seed=seed),
        True: _build("mlp", "full", "reference", True,
                     n=n, m=m, dim=dim, tau=tau, seed=seed, comm=comm),
    }
    compile_s, times, states = {}, {False: [], True: []}, {}
    for coded, (step, state, payload, _) in built.items():
        t0 = time.perf_counter()
        state, _aux = step(state, payload)
        _block(state)
        compile_s[coded] = time.perf_counter() - t0
        states[coded] = state
    for _ in range(reps):
        for coded, (step, _, payload, _) in built.items():
            t0 = time.perf_counter()
            states[coded], _aux = step(states[coded], payload)
            _block(states[coded])
            times[coded].append(time.perf_counter() - t0)
    paired = statistics.median(
        b / a for a, b in zip(times[False], times[True])
    )
    pack_spec = built[True][3]
    channel = make_channel(comm, pack_spec.size)
    return {
        "lane": f"fedspd/comm_{codec}",
        "codec": codec, "error_feedback": True, "n_clients": n,
        "compile_s": round(compile_s[True], 4),
        "round_ms": round(min(times[True]) * 1e3, 4),
        "round_ms_median": round(statistics.median(times[True]) * 1e3, 4),
        "fp32_round_ms": round(min(times[False]) * 1e3, 4),
        "paired_overhead_vs_fp32": round(paired, 3),
        "logical_model_bytes": pack_spec.model_bytes,
        "wire_model_bytes": channel.wire_model_bytes,
        "wire_ratio": round(channel.wire_ratio(pack_spec.model_bytes), 4),
    }


def bench_scan_rounds(*, n: int, m: int, dim: int, tau: int, rounds: int,
                      repeats: int, seed: int = 0,
                      cohort: int | None = None) -> dict:
    """Whole-experiment lanes through the driver's lax.scan engine.

    ``fedspd/scan_rounds``: all R rounds as ONE compiled program — the row
    asserts extras report exactly one compile and one host dispatch (the
    count is independent of ``rounds`` by construction), and the amortized
    per-round time (compile included) is the trend-gated metric.

    ``fedspd/cohort_n1024`` (``cohort=K``): the same scan program at
    N=1024 clients with a K-client per-round cohort — proves the compact
    active-plane gather keeps the big-N configuration CI-runnable (no
    OOM, still one compile)."""
    from repro.configs.paper_cnn import PaperExpConfig
    from repro.experiments import RunConfig, run_method

    exp = PaperExpConfig(
        n_clients=n, n_per_client=m, rounds=rounds, tau=tau,
        batch=min(16, m), avg_degree=4.0, model="mlp", dim=dim, n_classes=4,
    )
    data = make_mixture_classification(
        n_clients=n, n_clusters=2, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    cfg = RunConfig(eval_every=10**9, param_plane=True, scan_rounds=True,
                    cohort_size=cohort)
    walls, r = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = run_method("fedspd", data, exp, seed=seed, cfg=cfg)
        walls.append(time.perf_counter() - t0)
    assert r.extras["n_compiles"] == 1, r.extras
    assert r.extras["n_dispatches"] == 1, r.extras
    per_round = [w * 1e3 / rounds for w in walls]
    return {
        "lane": "fedspd/cohort_n1024" if cohort else "fedspd/scan_rounds",
        "n_clients": n, "rounds": rounds, "cohort_size": cohort,
        "n_compiles": r.extras["n_compiles"],
        "n_dispatches": r.extras["n_dispatches"],
        "run_s": round(min(walls), 4),
        "round_ms": round(min(per_round), 4),
        "round_ms_median": round(statistics.median(per_round), 4),
        "mean_acc": round(float(r.mean_acc), 4),
    }


def bench_straggler(*, n: int, m: int, dim: int, rounds: int,
                    repeats: int, seed: int = 0) -> dict:
    """``fedspd/straggler``: the client-heterogeneity engine
    (experiments/heterogeneity.py) at N=64 with 30% slow clients —
    straggler timeouts with lognormal jitter, light Bernoulli
    unavailability, and stale-gossip decay, the whole sweep scan-rolled
    into ONE compiled program (asserted). Trend-gates the masked-step
    overhead: activity draws + weighted adjacency + the bit-untouched
    row restore per round."""
    from repro.configs.paper_cnn import PaperExpConfig
    from repro.experiments import (
        ClientSystemModel,
        RunConfig,
        Scenario,
        run_method,
    )

    exp = PaperExpConfig(
        n_clients=n, n_per_client=m, rounds=rounds, tau=1,
        batch=min(16, m), avg_degree=4.0, model="mlp", dim=dim, n_classes=4,
    )
    data = make_mixture_classification(
        n_clients=n, n_clusters=2, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    het = ClientSystemModel(
        slow_fraction=0.3, slow_factor=4.0, time_budget=2.0, jitter=0.5,
        p_unavailable=0.05, staleness_gamma=0.9, seed=seed,
    )
    cfg = RunConfig(eval_every=10**9, param_plane=True, scan_rounds=True,
                    scenario=Scenario(system=het))
    walls, r = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = run_method("fedspd", data, exp, seed=seed, cfg=cfg)
        walls.append(time.perf_counter() - t0)
    assert r.extras["n_compiles"] == 1, r.extras
    assert r.extras["n_dispatches"] == 1, r.extras
    per_round = [w * 1e3 / rounds for w in walls]
    return {
        "lane": "fedspd/straggler",
        "n_clients": n, "rounds": rounds, "slow_fraction": 0.3,
        "n_compiles": r.extras["n_compiles"],
        "n_dispatches": r.extras["n_dispatches"],
        "run_s": round(min(walls), 4),
        "round_ms": round(min(per_round), 4),
        "round_ms_median": round(statistics.median(per_round), 4),
        "mean_acc": round(float(r.mean_acc), 4),
        "max_staleness": int(max(r.extras["staleness"])),
        "wire_bytes": float(r.wire_bytes),
    }


def bench_sparse(*, n: int, m: int, dim: int, tau: int, rounds: int,
                 repeats: int, seed: int = 0,
                 codec: str | None = None) -> dict:
    """DisPFL sparse-training lanes through the scan engine.

    ``fedspd/sparse_d20``: the masked round at density 0.2 (RigL
    prune/regrow every 4 rounds), all rounds scan-rolled into ONE
    compiled program — one compile + one host dispatch asserted, exactly
    like the dense scan lane. ``fedspd/sparse_comm_int8`` (``codec=
    "int8"``): the same masked round with the int8 + error-feedback wire
    codec stacked on top (mask-then-encode). Both rows carry the static
    sparse wire accounting (nnz payload + support bitmap) against the
    dense wire cost of the same codec."""
    from repro.comm.codecs import sparse_wire_model_bytes
    from repro.configs.paper_cnn import PaperExpConfig
    from repro.core.sparse import SparseConfig
    from repro.experiments import RunConfig, run_method

    sp = SparseConfig(density=0.2, prune_rate=0.2, regrow="rigl",
                      update_every=4)
    comm = CommConfig(codec=codec, error_feedback=True) if codec else None
    exp = PaperExpConfig(
        n_clients=n, n_per_client=m, rounds=rounds, tau=tau,
        batch=min(16, m), avg_degree=4.0, model="mlp", dim=dim, n_classes=4,
    )
    data = make_mixture_classification(
        n_clients=n, n_clusters=2, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    cfg = RunConfig(eval_every=10**9, param_plane=True, scan_rounds=True,
                    sparse=sp, comm=comm)
    walls, r = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = run_method("fedspd", data, exp, seed=seed, cfg=cfg)
        walls.append(time.perf_counter() - t0)
    assert r.extras["n_compiles"] == 1, r.extras
    assert r.extras["n_dispatches"] == 1, r.extras
    key = jax.random.PRNGKey(seed)

    def model_init(k):
        from repro.models.smallnets import make_classifier
        p, *_ = make_classifier("mlp", k, dim, 4)
        return p

    spec = make_pack_spec(jax.eval_shape(model_init, key))
    x = spec.size
    wire_cfg = comm or CommConfig(codec="fp32")
    sparse_wire = sparse_wire_model_bytes(wire_cfg, x, sp.k_active(x))
    dense_wire = (spec.model_bytes if comm is None
                  else make_channel(comm, x).wire_model_bytes)
    per_round = [w * 1e3 / rounds for w in walls]
    return {
        "lane": f"fedspd/sparse_comm_{codec}" if codec else
                "fedspd/sparse_d20",
        "n_clients": n, "rounds": rounds, "density": sp.density,
        "codec": codec or "fp32",
        "n_compiles": r.extras["n_compiles"],
        "n_dispatches": r.extras["n_dispatches"],
        "run_s": round(min(walls), 4),
        "round_ms": round(min(per_round), 4),
        "round_ms_median": round(statistics.median(per_round), 4),
        "mean_acc": round(float(r.mean_acc), 4),
        "wire_model_bytes": sparse_wire,
        "dense_wire_model_bytes": dense_wire,
        "wire_vs_dense": round(sparse_wire / dense_wire, 4),
        "wire_bytes": float(r.wire_bytes),
    }


def bench_telemetry_overhead(*, n: int, m: int, dim: int, tau: int,
                             reps: int, seed: int = 0) -> dict:
    """``fedspd/telemetry_overhead``: the traced round-metrics plane
    (telemetry/metrics.make_collector) spliced into the packed FedSPD
    round step vs the bare step — the SAME wrapper shape the experiment
    driver jits, timed with the interleaved paired protocol of
    ``bench_pair``. Pairing happens at the STEP level on purpose: at
    smoke sizes compile time dwarfs 32 rounds of execution, so a
    whole-run pairing would gate compile-time jitter, not collection
    cost. The acceptance bar is paired overhead within noise (<= 5%
    median); the scan-engine one-compile/one-dispatch claim with
    telemetry ON is asserted in tests/test_telemetry.py."""
    from repro.telemetry import TelemetryConfig
    from repro.telemetry.metrics import make_collector

    built = {p: _build("mlp", "full", "reference", True,
                       n=n, m=m, dim=dim, tau=tau, seed=seed)
             for p in (False, True)}
    adj = jnp.asarray(make_graph("er", n, 4.0, seed=seed).adj, jnp.float32)
    collect = make_collector(TelemetryConfig(), n_clusters=2, n_clients=n)

    steps = {}
    for p, (step, _, _, _) in built.items():
        if p:
            def step_on(st, b, _step=step):
                new, aux = _step(st, b)
                return new, aux, collect(st, new, adj)

            steps[p] = jax.jit(step_on)
        else:
            steps[p] = jax.jit(lambda st, b, _step=step: _step(st, b))
    compile_s, times, states = {}, {False: [], True: []}, {}
    for p, (_, state, payload, _) in built.items():
        t0 = time.perf_counter()
        out = steps[p](state, payload)
        _block(out)
        compile_s[p] = time.perf_counter() - t0
        states[p] = out[0]
    for _ in range(reps):
        for p, (_, _, payload, _) in built.items():
            t0 = time.perf_counter()
            out = steps[p](states[p], payload)
            _block(out)
            states[p] = out[0]
            times[p].append(time.perf_counter() - t0)
    paired = statistics.median(
        b / a for a, b in zip(times[False], times[True])
    )
    return {
        "lane": "fedspd/telemetry_overhead",
        "n_clients": n, "streams": 11,
        "compile_s": round(compile_s[True], 4),
        "round_ms": round(min(times[True]) * 1e3, 4),
        "round_ms_median": round(statistics.median(times[True]) * 1e3, 4),
        "off_round_ms": round(min(times[False]) * 1e3, 4),
        "paired_overhead_vs_off": round(paired, 3),
    }


def bench_mixture_qps(codec: str, *, s: int, dim: int, users: int,
                      batch: int, reps: int, seed: int = 0) -> dict:
    """``serve/mixture_qps`` lanes: personalized predictions/sec off the
    hot cluster plane (serve/ClusterPlaneServer) at simulated ``users``
    population cardinality.

    Every rep draws a FRESH heterogeneous request batch — ``batch`` user
    ids from the ``users``-sized population, each with its own Dirichlet
    mixture over the S clusters — and answers it in the server's single
    compiled predict step (mix → unpack → vmapped forward). Per-user
    models are never materialized; the population never exists on device —
    exactly the property that makes the 1e6-user north star servable. The
    fp32 lane exercises the einsum plane path, ``_int4`` the bit-packed
    fused Pallas kernel (kernels/mixture_mix_dequant4)."""
    import numpy as np

    from repro.comm.codecs import Channel, int4_pack
    from repro.serve import ClusterPlaneServer

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    _, apply, *_ = make_classifier("mlp", key, dim, 4)

    def model_init(k):
        p, *_ = make_classifier("mlp", k, dim, 4)
        return p

    spec = make_pack_spec(jax.eval_shape(model_init, key))
    plane = jnp.stack([pack(model_init(jax.random.PRNGKey(seed + i)), spec)
                       for i in range(s)])
    qblock = 64
    if codec == "fp32":
        server = ClusterPlaneServer(spec, plane=plane, apply_fn=apply)
    else:
        ch = Channel(CommConfig(codec=codec, block=qblock), spec.size)
        enc = ch.encode(plane, key, rounding="nearest")
        kw = {"plane_q": enc["q"]} if codec == "int8" else \
            {"plane_packed": int4_pack(enc["q"])}
        server = ClusterPlaneServer(spec, codec=codec, qblock=qblock,
                                    plane_scale=enc["scale"],
                                    apply_fn=apply, **kw)

    def request_batch():
        # ids drawn from the full population; mixtures are per-user
        # functions of the id (nothing per-user is ever materialized)
        ids = rng.integers(0, users, size=batch)
        u = rng.dirichlet(np.ones(s), size=batch).astype(np.float32)
        x = rng.normal(size=(batch, dim)).astype(np.float32)
        del ids
        return u, x

    u, x = request_batch()
    t0 = time.perf_counter()
    _block(server.predict(u, x))
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(reps):
        u, x = request_batch()
        t0 = time.perf_counter()
        _block(server.predict(u, x))
        times.append(time.perf_counter() - t0)
    med = statistics.median(times)
    assert server.n_compiles == 1, server.n_compiles
    return {
        "lane": ("serve/mixture_qps" if codec == "fp32"
                 else f"serve/mixture_qps_{codec}"),
        "codec": codec, "n_clusters": s, "n_params": spec.size,
        "users": users, "batch": batch,
        "compile_s": round(compile_s, 4),
        "round_ms": round(min(times) * 1e3, 4),
        "round_ms_median": round(med * 1e3, 4),
        "qps": round(batch / med, 1),
        "n_compiles": server.n_compiles,
        "n_dispatches": server.n_dispatches,
    }


def bench_method_pair(method: str, *, n: int, m: int, dim: int, tau: int,
                      reps: int, seed: int = 0) -> list[dict]:
    """Registry baseline steps, pytree vs packed (N, X)/(S, N, X) plane —
    the same interleaved paired protocol as ``bench_pair``, through the
    exact adapters the experiment driver uses (donated jitted step)."""
    from repro.configs.paper_cnn import PaperExpConfig
    from repro.experiments import build_context, get_method

    exp = PaperExpConfig(
        n_clients=n, n_per_client=m, rounds=1, tau=tau, batch=16,
        avg_degree=4.0, model="mlp", dim=dim, n_classes=4,
    )
    data = make_mixture_classification(
        n_clients=n, n_clusters=2, n_per_client=m, dim=dim, n_classes=4,
        seed=seed,
    )
    mth = get_method(method)
    built = {}
    for p in (False, True):
        ctx = build_context(data, exp, seed=seed,
                            options={"param_plane": p})
        state = mth.init(ctx, jax.random.PRNGKey(seed))
        step = jax.jit(mth.make_step(ctx), donate_argnums=0)
        built[p] = (step, state, ctx)
    key, lr = jax.random.PRNGKey(seed + 1), exp.lr0
    compile_s, times, states = {}, {False: [], True: []}, {}
    for p, (step, state, ctx) in built.items():
        t0 = time.perf_counter()
        state, _aux = step(state, ctx.train, key, lr)
        _block(state)
        compile_s[p] = time.perf_counter() - t0
        states[p] = state
    for _ in range(reps):
        for p, (step, _, ctx) in built.items():
            t0 = time.perf_counter()
            states[p], _aux = step(states[p], ctx.train, key, lr)
            _block(states[p])
            times[p].append(time.perf_counter() - t0)
    paired = statistics.median(
        a / b for a, b in zip(times[False], times[True])
    )
    return [{
        "lane": f"{method}/{'packed' if p else 'pytree'}",
        "method": method, "packed": p, "n_clients": n,
        "compile_s": round(compile_s[p], 4),
        "round_ms": round(min(times[p]) * 1e3, 4),
        "round_ms_median": round(statistics.median(times[p]) * 1e3, 4),
        "paired_speedup_vs_pytree": round(paired, 3) if p else 1.0,
    } for p in (False, True)]


def run(fast: bool = True, out: str = DEFAULT_OUT, reps: int | None = None):
    n, m, dim, tau = (8, 32, 16, 2) if fast else (16, 96, 16, 5)
    reps = reps or (80 if fast else 30)
    results = []
    for model in ("mlp", "conv"):
        for regime in ("full", "stream"):
            for backend in ("reference", "pallas"):
                pair = bench_pair(model, regime, backend,
                                  n=n, m=m, dim=dim, tau=tau, reps=reps)
                results.extend(pair)
                for r in pair:
                    print(f"{model:>5s} {regime:>6s} {backend:>9s} "
                          f"{'packed' if r['packed'] else 'pytree':>6s}  "
                          f"round {r['round_ms']:9.2f} ms   "
                          f"compile {r['compile_s']:6.2f} s")
    # baseline lanes run the stream-loop shape (train.py defaults): more
    # clients, τ=1 — the exchange-dominant regime the plane targets
    for method in BASELINE_METHODS:
        pair = bench_method_pair(method, n=16, m=m, dim=dim, tau=1,
                                 reps=reps)
        results.extend(pair)
        for r in pair:
            print(f"{r['lane']:>24s}  round {r['round_ms']:9.2f} ms   "
                  f"compile {r['compile_s']:6.2f} s")
    # compressed-communication lanes: codec overhead + wire-byte accounting
    comm_lanes = []
    for codec in COMM_CODECS:
        row = bench_comm_pair(codec, n=n, m=m, dim=dim, tau=tau, reps=reps)
        results.append(row)
        comm_lanes.append(row)
        print(f"{row['lane']:>24s}  round {row['round_ms']:9.2f} ms   "
              f"(fp32 {row['fp32_round_ms']:8.2f} ms)  wire "
              f"{row['wire_model_bytes']}/{row['logical_model_bytes']} B "
              f"= x{row['wire_ratio']}")
    # scenario-engine lane: traced per-round adjacency vs static closure
    dyn = bench_dynamic_graph(n=n, m=m, dim=dim, tau=tau, reps=reps)
    results.append(dyn)
    print(f"{dyn['lane']:>24s}  round {dyn['round_ms']:9.2f} ms   "
          f"(static {dyn['static_round_ms']:8.2f} ms)  overhead "
          f"x{dyn['paired_overhead_vs_static']}")
    # scan-rolled whole-experiment lanes (RunConfig.scan_rounds): one
    # compile + one dispatch, asserted inside bench_scan_rounds
    scan = bench_scan_rounds(n=n, m=m, dim=dim, tau=tau,
                             rounds=32 if fast else 64, repeats=2)
    results.append(scan)
    print(f"{scan['lane']:>24s}  round {scan['round_ms']:9.2f} ms   "
          f"({scan['rounds']} rounds in {scan['run_s']:.2f} s, "
          f"{scan['n_dispatches']} dispatch)")
    coh = bench_scan_rounds(n=1024, m=16, dim=dim, tau=1,
                            rounds=4 if fast else 8, repeats=1, cohort=32)
    results.append(coh)
    print(f"{coh['lane']:>24s}  round {coh['round_ms']:9.2f} ms   "
          f"(N={coh['n_clients']}, K={coh['cohort_size']}, "
          f"{coh['n_dispatches']} dispatch)")
    # client-heterogeneity lane: N=64, 30% slow clients, stragglers +
    # availability + staleness decay scan-rolled into one program
    stg = bench_straggler(n=64, m=16, dim=dim,
                          rounds=8 if fast else 16, repeats=2)
    results.append(stg)
    print(f"{stg['lane']:>24s}  round {stg['round_ms']:9.2f} ms   "
          f"(N={stg['n_clients']}, 30% slow, max stale "
          f"{stg['max_staleness']}, {stg['n_dispatches']} dispatch)")
    # sparse-training lanes: DisPFL masked round at density 0.2, plain
    # and stacked on the int8+EF wire codec, both scan-rolled (asserted)
    sparse_lanes = []
    for codec in (None, "int8"):
        row = bench_sparse(n=n, m=m, dim=dim, tau=tau,
                           rounds=8 if fast else 16, repeats=2, codec=codec)
        results.append(row)
        sparse_lanes.append(row)
        print(f"{row['lane']:>24s}  round {row['round_ms']:9.2f} ms   "
              f"(d={row['density']}, wire "
              f"{row['wire_model_bytes']}/{row['dense_wire_model_bytes']} B "
              f"= x{row['wire_vs_dense']}, {row['n_dispatches']} dispatch)")
    # telemetry lane: the traced round-metrics plane vs the bare step —
    # collection must stay within measurement noise (paired, step-level)
    tel = bench_telemetry_overhead(n=n, m=m, dim=dim, tau=tau, reps=reps)
    results.append(tel)
    print(f"{tel['lane']:>24s}  round {tel['round_ms']:9.2f} ms   "
          f"(off {tel['off_round_ms']:8.2f} ms)  overhead "
          f"x{tel['paired_overhead_vs_off']}")
    # mixture-serving lanes: personalized predictions/sec off the hot
    # cluster plane (fp32 einsum + bit-packed int4 fused kernel) at
    # simulated 1e6-user population cardinality
    serve_lanes = []
    for codec in ("fp32", "int4"):
        row = bench_mixture_qps(codec, s=4, dim=dim, users=1_000_000,
                                batch=64 if fast else 256,
                                reps=min(reps, 40))
        results.append(row)
        serve_lanes.append(row)
        print(f"{row['lane']:>24s}  batch {row['round_ms']:9.2f} ms   "
              f"({row['qps']:>9.1f} users/s, B={row['batch']}, "
              f"{row['n_compiles']} compile)")
    comparisons = []
    for model in ("mlp", "conv"):
        for regime in ("full", "stream"):
            for backend in ("reference", "pallas"):
                pair = {r["packed"]: r for r in results
                        if (r.get("model"), r.get("regime"), r.get("backend"))
                        == (model, regime, backend)}
                comparisons.append({
                    "lane": f"{model}/{regime}/{backend}",
                    "model": model, "regime": regime, "backend": backend,
                    "pytree_ms": pair[False]["round_ms"],
                    "packed_ms": pair[True]["round_ms"],
                    "speedup": pair[True]["paired_speedup_vs_pytree"],
                })
    for method in BASELINE_METHODS:
        pair = {r["packed"]: r for r in results
                if r.get("method") == method}
        comparisons.append({
            "lane": method, "method": method,
            "pytree_ms": pair[False]["round_ms"],
            "packed_ms": pair[True]["round_ms"],
            "speedup": pair[True]["paired_speedup_vs_pytree"],
        })
    payload = {
        "bench": "roundstep",
        "meta": {
            "jax": jax.__version__,
            "device_backend": jax.default_backend(),
            "smoke": fast,
            "sizes": {"n_clients": n, "n_per_client": m, "dim": dim,
                      "tau": tau, "reps": reps},
            "unix_time": int(time.time()),
        },
        "results": results,
        "comparisons": comparisons,
        "comm_lanes": comm_lanes,
        "sparse_lanes": sparse_lanes,
        "serve_lanes": serve_lanes,
        "telemetry_lanes": [tel],
    }
    out = os.path.abspath(out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print("\npacked-vs-pytree speedups "
          f"({'smoke' if fast else 'bench'} sizes):")
    for c in comparisons:
        print(f"  {c['lane']:>24s}  "
              f"{c['pytree_ms']:9.2f} -> {c['packed_ms']:9.2f} ms  "
              f"x{c['speedup']}")
    print(f"wrote {out}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="CI sizes (small clients/rounds)")
    mode.add_argument("--full", action="store_true",
                      help="bench sizes (the no-flag default)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    run(fast=args.smoke, out=args.out, reps=args.reps)


if __name__ == "__main__":
    main()
