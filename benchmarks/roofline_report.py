"""Deliverable (g): aggregate the dry-run roofline JSONs
(experiments/dryrun/*.json, produced by launch/dryrun.py) into the
per-(arch × shape × mesh) table used in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(fast: bool = True) -> dict:
    del fast
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "step": r["step_kind"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_ratio"],
        })
    if not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return {"rows": []}
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} {'step':8s} "
           f"{'compute_s':>11s} {'memory_s':>11s} {'coll_s':>11s} "
           f"{'bound':>10s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:9s} "
              f"{r['step']:8s} {r['compute_s']:11.3e} {r['memory_s']:11.3e} "
              f"{r['collective_s']:11.3e} {r['bottleneck']:>10s} "
              f"{r['useful_ratio']:7.3f}")
    out = {"rows": rows}
    save_result("roofline_report", out)
    return out


if __name__ == "__main__":
    run()
