"""Paper B.2.5 (Figure 9): data-quantity imbalance across clients — accuracy
vs imbalance ratio r between the largest and smallest data holders."""
from __future__ import annotations

from benchmarks.common import exp_config, fmt_table, save_result
from repro.data.synthetic import make_mixture_classification, make_unbalanced_quantity
from repro.experiments import RunConfig, run_method


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    rows = []
    for ratio in ([1, 4] if fast else [1, 3, 5, 9]):
        data = make_mixture_classification(
            n_clients=exp.n_clients, n_clusters=2,
            n_per_client=exp.n_per_client, dim=exp.dim,
            n_classes=exp.n_classes, seed=5, noise=0.25,
        )
        if ratio > 1:
            data = make_unbalanced_quantity(data, ratio=ratio, seed=1)
        quiet = RunConfig(eval_every=10**9)
        fed = run_method("fedspd", data, exp, seed=0, cfg=quiet)
        loc = run_method("local", data, exp, seed=0, cfg=quiet)
        rows.append({
            "ratio": ratio,
            "fedspd": round(fed.mean_acc, 4),
            "fedspd_min_client": round(float(fed.acc_per_client.min()), 4),
            "local": round(loc.mean_acc, 4),
        })
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["ratio", "fedspd", "fedspd_min_client", "local"],
                    "B.2.5: quantity imbalance"))
    save_result("fig9_unbalanced", out)
    return out


if __name__ == "__main__":
    run()
