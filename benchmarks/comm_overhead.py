"""Paper §6.3: communication overhead — bytes transmitted per round for
FedSPD (point-to-point, cluster-matched) vs FedAvg/FedSoft (multicast, one
model) vs FedEM (multicast, S models), plus the beyond-paper edge-colored
collective_permute schedule statistics."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import exp_config, fmt_table, save_result
from repro.core.gossip import GossipSpec, round_comm_bytes
from repro.graphs.coloring import schedule_stats
from repro.graphs.topology import make_graph
from repro.models.smallnets import make_classifier
from repro.utils.pytree import tree_bytes


def run(fast: bool = True) -> dict:
    exp = exp_config(fast, n_clients=24)  # sparse graphs need room
    key = jax.random.PRNGKey(0)
    params, *_ = make_classifier(exp.model, key, exp.dim, exp.n_classes)
    model_b = tree_bytes(params)
    rows = []
    for s_clusters in (2, 4):
        for deg in ([4.0, 8.0] if fast else [4.0, 6.0, 8.0, 12.0]):
            g = make_graph("er", exp.n_clients, deg, seed=0)
            spec = GossipSpec.from_graph(g)
            # expected over selections: average 100 rounds of random s
            rng = np.random.default_rng(0)
            fedspd = np.mean([
                float(round_comm_bytes(
                    spec, jnp.asarray(rng.integers(0, s_clusters,
                                                   exp.n_clients)),
                    model_b, point_to_point=True))
                for _ in range(100)
            ])
            multicast_1 = float(round_comm_bytes(
                spec, jnp.zeros(exp.n_clients, jnp.int32), model_b,
                point_to_point=False))
            fedem = multicast_1 * s_clusters
            stats = schedule_stats(g)
            rows.append({
                "S": s_clusters, "avg_degree": round(g.avg_degree, 2),
                "fedspd_MB": fedspd / 1e6,
                "fedavg_fedsoft_MB": multicast_1 / 1e6,
                "fedem_MB": fedem / 1e6,
                "fedspd_vs_fedem": fedspd / fedem,
                "permute_colors": stats["n_colors"],
            })
            print(rows[-1])
    out = {"rows": rows, "model_bytes": model_b}
    print(fmt_table(
        rows,
        ["S", "avg_degree", "fedspd_MB", "fedavg_fedsoft_MB", "fedem_MB",
         "fedspd_vs_fedem", "permute_colors"],
        "§6.3: per-round communication (expected over cluster selections)"))
    save_result("comm_overhead", out)
    return out


if __name__ == "__main__":
    run()
