"""Paper Table 7 (B.2.4): FedSPD under a dynamic network topology — each
round, existing edges drop with probability p and new edges are added to
keep average degree roughly constant.

Registry port: the FedSPD state persists across graph changes; only the
context (and hence the jitted step) is rebuilt on the rounds where the
topology is rewired.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.experiments import build_context, get_method
from repro.graphs.topology import make_graph, rewire


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    m = get_method("fedspd")
    rows = []
    for p_rewire in ([0.0, 0.2] if fast else [0.0, 0.1, 0.2, 0.3]):
        graph = make_graph(exp.graph_kind, exp.n_clients, exp.avg_degree,
                           seed=0)
        ctx = build_context(data, exp, graph=graph, seed=0)
        key = jax.random.PRNGKey(0)
        k_init, k_run, k_eval = jax.random.split(key, 3)
        state = m.init(ctx, k_init)
        step = jax.jit(m.make_step(ctx))
        for r in range(exp.rounds):
            # dynamic topology: rebuild the context (and jitted step) every
            # round the graph changes; the method state carries over
            if p_rewire > 0 and r > 0:
                graph = rewire(graph, p_rewire, seed=100 * r)
                # only the graph changed: swap it in place of rebuilding the
                # whole context (model fns + device-put of train/test)
                ctx = dataclasses.replace(ctx, graph=graph)
                step = jax.jit(m.make_step(ctx))
            k_run, k = jax.random.split(k_run)
            state, _ = step(state, ctx.train, k, exp.lr0 * exp.lr_decay ** r)
        acc = float(np.mean(m.evaluate(ctx, state, k_eval, ctx.test)))
        rows.append({"p_rewire": p_rewire, "acc": round(acc, 4)})
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["p_rewire", "acc"],
                    "Table 7 analogue: dynamic topology"))
    save_result("table7_dynamic_topology", out)
    return out


if __name__ == "__main__":
    run()
