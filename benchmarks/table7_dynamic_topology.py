"""Paper Table 7 (B.2.4): FedSPD under a dynamic network topology — each
round, existing edges drop with probability p and new edges are added to
keep average degree roughly constant."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import exp_config, fmt_table, mixture_data, save_result
from repro.baselines.common import per_client_eval
from repro.core import (
    FedSPDConfig, GossipSpec, final_phase, make_round_step, seeded_init,
)
from repro.graphs.topology import make_graph, rewire
from repro.models.smallnets import make_classifier


def run(fast: bool = True) -> dict:
    exp = exp_config(fast)
    data = mixture_data(exp)
    key = jax.random.PRNGKey(0)
    _, apply_fn, loss_fn, pel_fn, acc_fn = make_classifier(
        exp.model, key, data.x.shape[-1], data.n_classes)

    def model_init(k):
        p, *_ = make_classifier(exp.model, k, data.x.shape[-1], data.n_classes)
        return p

    train = {"inputs": jnp.asarray(data.x), "targets": jnp.asarray(data.y)}
    test = {"inputs": jnp.asarray(data.x_test), "targets": jnp.asarray(data.y_test)}
    rows = []
    for p_rewire in ([0.0, 0.2] if fast else [0.0, 0.1, 0.2, 0.3]):
        fcfg = FedSPDConfig(n_clients=exp.n_clients, n_clusters=2,
                            tau=exp.tau, batch=exp.batch, lr0=exp.lr0,
                            tau_final=exp.tau_final)
        graph = make_graph(exp.graph_kind, exp.n_clients, exp.avg_degree,
                           seed=0)
        state = seeded_init(key, model_init, fcfg, loss_fn, train)
        for r in range(exp.rounds):
            # dynamic topology: rebuild the gossip spec (and hence the jitted
            # step) every round the graph changes
            if p_rewire > 0 and r > 0:
                graph = rewire(graph, p_rewire, seed=100 * r)
            spec = GossipSpec.from_graph(graph)
            step = jax.jit(make_round_step(loss_fn, pel_fn, spec, fcfg))
            state, _ = step(state, train)
        pers = final_phase(state, loss_fn, train, fcfg)
        acc = float(np.mean(per_client_eval(acc_fn, pers, test)))
        rows.append({"p_rewire": p_rewire, "acc": round(acc, 4)})
        print(rows[-1])
    out = {"rows": rows}
    print(fmt_table(rows, ["p_rewire", "acc"],
                    "Table 7 analogue: dynamic topology"))
    save_result("table7_dynamic_topology", out)
    return out


if __name__ == "__main__":
    run()
