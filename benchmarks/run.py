"""Run the full benchmark suite: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run           # fast (CPU-sized)
  PYTHONPATH=src python -m benchmarks.run --full    # paper-scale
  PYTHONPATH=src python -m benchmarks.run --only table23_baselines
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

MODULES = [
    "table23_baselines",      # Tables 2-3 (+ Fig 3 spread)
    "fig2_convergence",       # Figure 2
    "fig4_connectivity",      # Figure 4 + Tables 4-5
    "table6_local_epochs",    # Table 6 / B.2.1
    "final_phase_ablation",   # B.2.2
    "clusters_ablation",      # B.2.3 / Figure 7
    "table7_dynamic_topology",  # Table 7 / B.2.4
    "fig9_unbalanced",        # B.2.5 / Figure 9
    "table8_dp",              # Table 8 / B.2.6
    "comm_overhead",          # §6.3
    "roofline_report",        # deliverable (g) aggregation
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n{'=' * 72}\n== benchmarks.{name}\n{'=' * 72}")
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(fast=not args.full)
            print(f"[{name} done in {time.time() - t0:.1f}s]")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        raise SystemExit(1)
    print("\nall benchmarks completed; results in benchmarks/results/")


if __name__ == "__main__":
    main()
